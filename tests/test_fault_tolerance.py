"""Fault tolerance: checkpoints (atomicity, integrity, quarantine), trainer
kill/resume determinism, straggler watchdog."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.models.model import RuntimeFlags
from repro.train.trainer import StragglerStats, Trainer

FLAGS = RuntimeFlags(remat=False, chunked_attention=False)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": jnp.zeros((2, 2), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(7, tree)
    restored, step = mgr.restore(tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.steps() == [3, 4]


def test_corrupt_checkpoint_quarantined(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest
    victim = next((tmp_path / "step_00000002").glob("*.npy"))
    victim.write_bytes(b"garbage")
    restored, step = mgr.restore(tree)
    assert step == 1  # fell back
    assert (tmp_path / "step_00000002.corrupt").exists()


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_atomic_commit_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree())
    assert not list(tmp_path.glob("*.tmp"))
    assert (tmp_path / "step_00000005" / "manifest.json").exists()


class _Boom(RuntimeError):
    pass


def test_trainer_kill_and_resume_bit_identical(tmp_path):
    """Train 6 steps straight vs. crash-at-4 + resume: identical final loss
    (deterministic data pipeline + checkpointed state)."""
    cfg = get_config("olmo-1b").reduced()

    t_ref = Trainer(cfg, seq_len=32, global_batch=2, flags=FLAGS,
                    ckpt_dir=str(tmp_path / "ref"), ckpt_every=2, seed=0)
    ref_hist = t_ref.train(6)

    def bomb(step):
        if step == 4:
            raise _Boom()

    t1 = Trainer(cfg, seq_len=32, global_batch=2, flags=FLAGS,
                 ckpt_dir=str(tmp_path / "x"), ckpt_every=2, seed=0,
                 failure_hook=bomb)
    with pytest.raises(_Boom):
        t1.train(6)

    t2 = Trainer(cfg, seq_len=32, global_batch=2, flags=FLAGS,
                 ckpt_dir=str(tmp_path / "x"), ckpt_every=2, seed=0)
    assert t2.maybe_resume()
    assert t2.step == 4
    hist = t2.train(6)
    assert hist[-1]["step"] == 6
    np.testing.assert_allclose(hist[-1]["loss"], ref_hist[-1]["loss"],
                               rtol=1e-5)


def test_straggler_watchdog_flags_outliers():
    st = StragglerStats()
    for _ in range(20):
        st.observe(0.1)
    assert st.observe(5.0) is True
    assert st.flagged == 1
    assert st.observe(0.1) is False


def test_elastic_restore_different_structure_dtype(tmp_path):
    """Checkpoints restore onto differently-typed abstract trees (the
    device-count-independent contract; cross-device-count restore is
    exercised in test_distribution via subprocesses)."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(3, tree)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    restored, step = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# Durable Forge service: journaled submits survive a dispatcher crash
# and a restarted service resumes them — exactly once
# ----------------------------------------------------------------------

import json as _json
import time as _time

from repro.aibench import build_program, load_specs
from repro.core.config import ForgeConfig
from repro.core.engine import KernelJob
from repro.core.faults import FaultPlan
from repro.serve.service import ForgeService, ServiceConfig

_SPECS = {s.name: s for s in load_specs()}
_NAMES = sorted(_SPECS)
_CONFIG = ForgeConfig(max_iterations=1)


def _kernel_job(name):
    s = _SPECS[name]
    return KernelJob(s.name,
                     build_program(s.builder, s.dims("ci"), "naive",
                                   meta=s.meta),
                     build_program(s.builder, s.dims("bench"), "naive",
                                   meta=s.meta),
                     tags=tuple(s.tags), target_dtype=s.target_dtype,
                     rtol=s.rtol, atol=s.atol, meta=dict(s.meta))


def _crash_service(journal, plan, submits):
    """Run a service against *journal* with *plan* armed, submit the
    given (name, client) pairs, wait for the injected dispatcher crash,
    and tear down the dead process. Returns the receipts."""
    svc = ForgeService(_CONFIG,
                       service_config=ServiceConfig(wave_size=1),
                       journal_path=str(journal), fault_plan=plan)
    receipts = [svc.submit_job(_kernel_job(name), client=client)
                for name, client in submits]
    deadline = _time.monotonic() + 300
    while not svc.dispatcher_crashed:
        assert _time.monotonic() < deadline, "dispatcher never crashed"
        _time.sleep(0.05)
    svc.shutdown(drain=False)
    return receipts


def test_service_crash_restart_recovers_every_job_exactly_once(tmp_path):
    """Crash before the wave's terminal journal commit: the journal still
    says "queued", so a restarted service re-runs every job — each
    exactly once, in the original order, ending done with a report."""
    journal = tmp_path / "svc.wal"
    plan = FaultPlan(crash_dispatcher_wave=1,
                     crash_dispatcher_point="before-journal")
    receipts = _crash_service(journal, plan,
                              [(_NAMES[0], "t-a"), (_NAMES[1], "t-b")])
    assert plan.fired.get("crash_dispatcher:before-journal") == 1

    svc2 = ForgeService.recover(str(journal), config=_CONFIG,
                                service_config=ServiceConfig(wave_size=1))
    try:
        js = svc2.journal_stats()
        assert js["jobs_recovered"] == 2 and js["jobs_requeued"] == 2
        statuses = [svc2.wait(r["job_id"], timeout=300) for r in receipts]
        for st, (name, client) in zip(statuses,
                                      [(_NAMES[0], "t-a"),
                                       (_NAMES[1], "t-b")]):
            assert st["state"] == "done"
            assert st["name"] == name and st["client"] == client
            assert st["report"] is not None
            assert st["events"] == len(st["report"]["jobs"][0]["stages"])
        # exactly once: the recovered service's engine ran 2 jobs — no
        # job was lost, none ran twice
        assert svc2.forge.stats.jobs == 2
    finally:
        svc2.shutdown(drain=True)


def test_service_crash_after_journal_restores_done_without_rerun(tmp_path):
    """Crash after the terminal commit: wave 1's job is journal-done, so
    recovery restores its report without re-running it; only the still-
    queued job re-executes."""
    journal = tmp_path / "svc.wal"
    plan = FaultPlan(crash_dispatcher_wave=1,
                     crash_dispatcher_point="after-journal")
    receipts = _crash_service(journal, plan,
                              [(_NAMES[0], "t-a"), (_NAMES[1], "t-a")])

    svc2 = ForgeService.recover(str(journal), config=_CONFIG,
                                service_config=ServiceConfig(wave_size=1))
    try:
        js = svc2.journal_stats()
        assert js["jobs_recovered"] == 2 and js["jobs_requeued"] == 1
        first = svc2.status(receipts[0]["job_id"])
        assert first["state"] == "done"          # served from the journal
        assert first["report"] is not None
        second = svc2.wait(receipts[1]["job_id"], timeout=300)
        assert second["state"] == "done"
        assert svc2.forge.stats.jobs == 1        # ONLY the queued job ran
    finally:
        svc2.shutdown(drain=True)


def test_service_recovery_preserves_dedup_attachment(tmp_path):
    """A deduped (attached) submission journals its attachment and, after
    recovery, mirrors the primary's report — the engine still runs the
    shared job once."""
    journal = tmp_path / "svc.wal"
    plan = FaultPlan(crash_dispatcher_wave=1,
                     crash_dispatcher_point="before-journal")
    receipts = _crash_service(
        journal, plan,
        [(_NAMES[0], "t-a"), (_NAMES[0], "t-b"), (_NAMES[1], "t-a")])
    assert receipts[1]["deduped"] is True
    assert receipts[1]["attached_to"] == receipts[0]["job_id"]

    svc2 = ForgeService.recover(str(journal), config=_CONFIG,
                                service_config=ServiceConfig(wave_size=1))
    try:
        js = svc2.journal_stats()
        # 3 jobs recovered; 2 primaries requeued (the attachment rides
        # its primary rather than queueing)
        assert js["jobs_recovered"] == 3 and js["jobs_requeued"] == 2
        s_primary = svc2.wait(receipts[0]["job_id"], timeout=300)
        s_attached = svc2.wait(receipts[1]["job_id"], timeout=300)
        s_other = svc2.wait(receipts[2]["job_id"], timeout=300)
        assert {s_primary["state"], s_attached["state"],
                s_other["state"]} == {"done"}
        assert (_json.dumps(s_primary["report"], sort_keys=True)
                == _json.dumps(s_attached["report"], sort_keys=True))
        assert svc2.forge.stats.jobs == 2        # dedup held through crash
    finally:
        svc2.shutdown(drain=True)


def test_service_monotonic_durations(tmp_path):
    """wait_s / run_s come from the monotonic clock and survive into the
    status dict; wall-clock timestamps remain for display."""
    svc = ForgeService(_CONFIG,
                       journal_path=str(tmp_path / "svc.wal"))
    try:
        r = svc.submit_job(_kernel_job(_NAMES[0]), client="t-a")
        st = svc.wait(r["job_id"], timeout=300)
        assert st["wait_s"] is not None and st["wait_s"] >= 0.0
        assert st["run_s"] is not None and st["run_s"] > 0.0
        assert st["created_s"] > 1e9             # wall clock, for display
        stats = svc.stats()
        assert stats["uptime_s"] >= 0.0
        assert stats["journal"]["records"] >= 2  # submit + terminal
    finally:
        svc.shutdown(drain=True)
