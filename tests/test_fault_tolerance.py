"""Fault tolerance: checkpoints (atomicity, integrity, quarantine), trainer
kill/resume determinism, straggler watchdog."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.models.model import RuntimeFlags
from repro.train.trainer import StragglerStats, Trainer

FLAGS = RuntimeFlags(remat=False, chunked_attention=False)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": jnp.zeros((2, 2), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(7, tree)
    restored, step = mgr.restore(tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.steps() == [3, 4]


def test_corrupt_checkpoint_quarantined(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest
    victim = next((tmp_path / "step_00000002").glob("*.npy"))
    victim.write_bytes(b"garbage")
    restored, step = mgr.restore(tree)
    assert step == 1  # fell back
    assert (tmp_path / "step_00000002.corrupt").exists()


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_atomic_commit_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree())
    assert not list(tmp_path.glob("*.tmp"))
    assert (tmp_path / "step_00000005" / "manifest.json").exists()


class _Boom(RuntimeError):
    pass


def test_trainer_kill_and_resume_bit_identical(tmp_path):
    """Train 6 steps straight vs. crash-at-4 + resume: identical final loss
    (deterministic data pipeline + checkpointed state)."""
    cfg = get_config("olmo-1b").reduced()

    t_ref = Trainer(cfg, seq_len=32, global_batch=2, flags=FLAGS,
                    ckpt_dir=str(tmp_path / "ref"), ckpt_every=2, seed=0)
    ref_hist = t_ref.train(6)

    def bomb(step):
        if step == 4:
            raise _Boom()

    t1 = Trainer(cfg, seq_len=32, global_batch=2, flags=FLAGS,
                 ckpt_dir=str(tmp_path / "x"), ckpt_every=2, seed=0,
                 failure_hook=bomb)
    with pytest.raises(_Boom):
        t1.train(6)

    t2 = Trainer(cfg, seq_len=32, global_batch=2, flags=FLAGS,
                 ckpt_dir=str(tmp_path / "x"), ckpt_every=2, seed=0)
    assert t2.maybe_resume()
    assert t2.step == 4
    hist = t2.train(6)
    assert hist[-1]["step"] == 6
    np.testing.assert_allclose(hist[-1]["loss"], ref_hist[-1]["loss"],
                               rtol=1e-5)


def test_straggler_watchdog_flags_outliers():
    st = StragglerStats()
    for _ in range(20):
        st.observe(0.1)
    assert st.observe(5.0) is True
    assert st.flagged == 1
    assert st.observe(0.1) is False


def test_elastic_restore_different_structure_dtype(tmp_path):
    """Checkpoints restore onto differently-typed abstract trees (the
    device-count-independent contract; cross-device-count restore is
    exercised in test_distribution via subprocesses)."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(3, tree)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    restored, step = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
