"""IR: graph construction, interpreter, rewrites (semantic preservation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ir import GraphBuilder
from repro.ir.graph import Graph, retype_graph
from repro.ir.interpreter import evaluate, make_inputs, make_params
from repro.ir.rewrite import RULES, find_rewrites


def _gemm_chain(M=64, N=48, K=32, dtype="float32"):
    b = GraphBuilder("g", dtype=dtype)
    x = b.input((M, K), name="x")
    w = b.param((K, N), name="w")
    mm = b.matmul(x, w, name="mm")
    sc = b.scale(mm, value=0.5, name="sc")
    sm = b.reduce_sum(sc, axes=(1,), name="sum")
    return b.done(sm)


def test_graph_shapes_inferred():
    g = _gemm_chain()
    assert g.node("mm").shape == (64, 48)
    assert g.node("sum").shape == (64,)


def test_toposort_after_redirect():
    g = _gemm_chain()
    rw = find_rewrites(g, rules=["matmul_reduce_to_vecmat"])
    # blocked by the scale in between; fold it first
    rw = find_rewrites(g, rules=["fold_scale_into_weights"])[0]
    g2 = rw.apply(g)
    order = [n.name for n in g2.toposorted()]
    for n in g2.toposorted():
        for i in n.inputs:
            assert order.index(i) < order.index(n.name)


def test_dce_removes_dead_nodes():
    b = GraphBuilder("g")
    x = b.input((8, 8), name="x")
    live = b.relu(x, name="live")
    b.tanh(x, name="dead")
    g = b.done(live)
    g.dce()
    assert "dead" not in g.nodes


def test_evaluate_matches_jnp():
    g = _gemm_chain()
    params = make_params(g)
    inputs = make_inputs(g)
    out = evaluate(g, inputs, params)["sum"]
    want = jnp.sum(inputs["x"] @ params["w"] * 0.5, axis=1)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_retype_graph():
    g = _gemm_chain(dtype="float64")
    g2 = retype_graph(g, lambda d: "float32" if d == "float64" else d)
    assert all(n.dtype != "float64" for n in g2.toposorted())
    assert g.node("x").dtype == "float64"  # original untouched


@pytest.mark.parametrize("rule", sorted(RULES))
def test_every_rule_preserves_semantics(rule):
    """Apply each rewrite rule where it matches; outputs must agree."""
    graphs = {
        "matmul_reduce_to_vecmat": _mm_sum_graph,
        "fold_scale_into_weights": _gemm_chain,
        "mean_to_sum_scale": lambda: _mean_graph(),
        "cse": lambda: _dup_graph(),
        "eliminate_identities": lambda: _noop_graph(),
        "transpose_elimination": lambda: _transpose_graph(),
        "tree_reduction": lambda: _serial_graph(),
        "fold_bn_into_conv": lambda: _bn_graph(),
    }
    g = graphs[rule]()
    rewrites = find_rewrites(g, rules=[rule])
    assert rewrites, f"rule {rule} found no match on its test graph"
    g2 = rewrites[0].apply(g)
    params = make_params(g)
    inputs = make_inputs(g)
    p2 = {k: v for k, v in params.items()
          if k in {p.name for p in g2.params()}}
    o1 = list(evaluate(g, inputs, params).values())
    o2 = list(evaluate(g2, inputs, p2).values())
    for a, b in zip(o1, o2):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=1e-4, atol=1e-4)


def _mm_sum_graph():
    b = GraphBuilder("g")
    x = b.input((64, 32), name="x")
    w = b.param((32, 48), name="w")
    mm = b.matmul(x, w, name="mm")
    return b.done(b.reduce_sum(mm, axes=(1,), name="sum"))


def _mean_graph():
    b = GraphBuilder("g")
    x = b.input((32, 16), name="x")
    w = b.param((16, 24), name="w")
    mm = b.matmul(x, w, name="mm")
    return b.done(b.reduce_mean(mm, axes=(1,), name="mean"))


def _dup_graph():
    b = GraphBuilder("g")
    x = b.input((16, 16), name="x")
    w = b.param((16, 16), name="w")
    m1 = b.matmul(x, w, name="m1")
    m2 = b.matmul(x, w, name="m2")
    return b.done(b.add(m1, m2, name="add"))


def _noop_graph():
    b = GraphBuilder("g")
    x = b.input((16, 16), name="x")
    d = b.dropout(x, name="drop")
    return b.done(b.relu(d, name="act"))


def _transpose_graph():
    b = GraphBuilder("g")
    x = b.input((16, 24), name="x")
    w = b.param((32, 24), name="w")
    wt = b.transpose(w, perm=(1, 0), name="wt")
    return b.done(b.matmul(x, wt, name="mm"))


def _serial_graph():
    b = GraphBuilder("g")
    x = b.input((16, 64), name="x")
    w = b.param((64, 32), name="w")
    mm = b.matmul(x, w, name="mm")
    s = b.g.add("reduce_sum", (mm,), name="s", axes=(1,), accumulate="serial")
    return b.done(s)


def _bn_graph():
    b = GraphBuilder("g")
    x = b.input((2, 4, 8, 8), name="x")
    w = b.param((8, 4, 3, 3), name="w")
    scale = b.param((8,), name="scale", init="uniform01")
    bias = b.param((8,), name="bias")
    mean = b.param((8,), name="mean")
    var = b.param((8,), name="var", init="uniform01")
    cv = b.conv2d(x, w, name="conv")
    bn = b.batchnorm(cv, scale, bias, mean, var, name="bn")
    return b.done(bn)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 12), n=st.integers(2, 12), k=st.integers(2, 12),
       seed=st.integers(0, 100))
def test_gemm_elimination_property(m, n, k, seed):
    """sum(x@W, axis=1) == x @ W.sum(0) for arbitrary shapes/seeds."""
    b = GraphBuilder("g")
    x = b.input((m * 8, k * 8), name="x")
    w = b.param((k * 8, n * 8), name="w")
    mm = b.matmul(x, w, name="mm")
    g = b.done(b.reduce_sum(mm, axes=(1,), name="s"))
    rw = find_rewrites(g, rules=["matmul_reduce_to_vecmat"])[0]
    g2 = rw.apply(g)
    params = make_params(g, seed=seed)
    inputs = make_inputs(g, seed=seed + 1)
    o1 = list(evaluate(g, inputs, params).values())[0]
    o2 = list(evaluate(g2, inputs, params).values())[0]
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-3)
    # and the rewritten graph must not contain a full-size matmul
    mms = [nd for nd in g2.toposorted() if nd.op == "matmul"]
    assert all(nd.shape[-1] == 1 or nd.shape[-2] == 1 for nd in mms)
