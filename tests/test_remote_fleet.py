"""Distributed worker fleet: remote-backend result equivalence, worker
loss + re-dispatch, handshake version/policy rejection, graceful drain,
the unified observer protocol, and the client-side wait/stream fixes."""

import json
import os
import pathlib
import socket
import subprocess
import sys
import threading
import time

import pytest

from repro.aibench import build_program, load_specs
from repro.core import (Forge, ForgeConfig, ForgeObserver, KernelJob,
                        CallbackObserver, WireVersionError, job_codec)
from repro.core import remote
from repro.core.fleet import FleetCoordinator
from repro.core.job_codec import WireDecodeError
from repro.core.pipeline import ForgePipeline
from repro.serve.client import StreamInterrupted, _poll_backoff

SPECS = {s.name: s for s in load_specs()}
SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


def _job(name, rename=None):
    s = SPECS[name]
    j = KernelJob(s.name,
                  build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
                  build_program(s.builder, s.dims("bench"), "naive",
                                meta=s.meta),
                  tags=tuple(s.tags), target_dtype=s.target_dtype,
                  rtol=s.rtol, atol=s.atol, meta=dict(s.meta))
    if rename:
        j.name = rename
    return j


def _twin_job(name="gemm_bias_gelu_twin"):
    s = SPECS["gemm_bias_gelu"]
    dims = {k: max(64, v // 2) for k, v in s.dims("bench").items()}
    ci = {k: max(32, v // 2) for k, v in s.dims("ci").items()}
    return KernelJob(name,
                     build_program(s.builder, ci, "naive", meta=s.meta),
                     build_program(s.builder, dims, "naive", meta=s.meta),
                     tags=tuple(s.tags), target_dtype=s.target_dtype,
                     rtol=s.rtol, atol=s.atol, meta=dict(s.meta))


def _jobs():
    """Leader + unrelated job + family twin (transfer) + duplicate twin
    (in-phase coalescing) — the same shape the process-backend test uses,
    so every dispatch path crosses the socket."""
    return [_job("gemm_bias_gelu"), _job("matmul_t_gelu"),
            _twin_job(), _twin_job("gemm_bias_gelu_twin2")]


def _comparable(report) -> str:
    """Byte-comparable form of a report: the full as_dict minus the two
    keys that legitimately differ across backends (config carries
    execution_backend; verify counters depend on cache locality)."""
    d = report.as_dict()
    d.pop("config")
    d.pop("verify_stats")
    return json.dumps(d, sort_keys=True)


def _spawn_worker(address, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(SRC) + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else str(SRC))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.core.remote_worker",
         "--connect", address, *extra],
        env=env, stdout=subprocess.DEVNULL)


@pytest.fixture(scope="module")
def serial_report():
    """One serial reference run of the canonical job set (the remote
    equivalence and worker-kill tests both compare against it)."""
    forge = Forge(ForgeConfig(execution_backend="serial"))
    report = forge.optimize_batch(_jobs())
    forge.close()
    return report


# ----------------------------------------------------------------------
# remote backend end-to-end: equivalence, streaming, warm replay, drain
# ----------------------------------------------------------------------

def test_remote_backend_end_to_end(serial_report):
    events = []

    class Obs(ForgeObserver):
        def on_stage(self, e):
            events.append(("stage", e.job_name, e.record.stage))

        def on_job(self, e):
            events.append(("job", e.result.job.name))

        def on_seed_transfer(self, e):
            events.append(("transfer", e.result.job.name))

    forge = Forge(ForgeConfig(execution_backend="remote", workers=2),
                  observers=[Obs()])
    try:
        cold = forge.optimize_batch(_jobs())
        # cold run: byte-equivalent to the serial reference (everything
        # except the backend name and the verify-cache counters)
        assert _comparable(cold) == _comparable(serial_report)

        # fleet telemetry: both spawned workers joined, none were lost
        executor = forge.engine._get_executor()
        assert executor.fleet.workers_joined == 2
        assert executor.fleet.workers_lost == 0

        # transfer and in-phase duplicate coalescing crossed the socket
        assert cold.results[2].transfer == serial_report.results[2].transfer
        assert cold.results[3].cache_hit

        # stage events streamed back from workers; job events fired once
        # per job; transfer events only for transferred jobs
        assert [e for e in events if e[0] == "stage"]
        assert len([e for e in events if e[0] == "job"]) == 4
        if cold.transfers:
            assert [e for e in events if e[0] == "transfer"]

        # warm run replays from the parent-held store through the fleet
        warm = forge.optimize_batch(_jobs())
        assert all(r.cache_hit for r in warm.results)

        # worker history deltas merged back into the parent history
        assert forge.history.snapshot_priors()

        procs = list(executor.fleet._procs)
    finally:
        forge.close()
    # graceful drain: every spawned worker exited cleanly
    assert [p.returncode for p in procs] == [0, 0]


# ----------------------------------------------------------------------
# robustness: worker killed mid-run -> re-dispatch, same bytes as serial
# ----------------------------------------------------------------------

def test_worker_kill_redispatch_byte_equivalent(serial_report):
    cfg = ForgeConfig(execution_backend="remote", workers=2,
                      fleet_spawn_workers=0, fleet_heartbeat_s=0.5,
                      fleet_heartbeat_timeout_s=3.0)
    forge = Forge(cfg)
    healthy = doomed = None
    try:
        executor = forge.engine._get_executor()
        fleet = executor.fleet
        healthy = _spawn_worker(fleet.address)
        # --die-after 0: exits (code 17) upon receiving its first job
        # task — after dispatch, before any work, forcing a re-dispatch
        doomed = _spawn_worker(fleet.address, "--die-after", "0")
        fleet.wait_for_workers(2, timeout=120)

        report = forge.optimize_batch(_jobs())

        assert doomed.wait(timeout=30) == 17
        assert fleet.workers_lost == 1
        assert fleet.tasks_redispatched >= 1
        # the re-dispatched job merged exactly once: the report is
        # byte-equivalent to the serial reference
        assert _comparable(report) == _comparable(serial_report)
    finally:
        forge.close()
        # external workers exit on their own after the drain frame; give
        # them a grace window before the hard-kill fallback
        for p in (healthy, doomed):
            if p is not None:
                try:
                    p.wait(timeout=30)
                except subprocess.TimeoutExpired:
                    p.kill()
                    p.wait()
    assert healthy.returncode == 0


# ----------------------------------------------------------------------
# handshake: version and policy-signature rejection
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def coordinator():
    cfg = ForgeConfig()
    coord = FleetCoordinator(ForgePipeline.from_config(cfg), cfg,
                             spawn_workers=0).start()
    yield coord
    coord.close(graceful=False)


def _handshake(coordinator, hello):
    host, port = remote.parse_address(coordinator.address)
    sock = socket.create_connection((host, port), timeout=10)
    try:
        sock.settimeout(10)
        remote.send_frame(sock, hello)
        return remote.recv_frame(sock)
    finally:
        sock.close()


def test_handshake_rejects_wire_version_mismatch(coordinator):
    reply = _handshake(coordinator, remote.hello_frame(
        pid=1, host="test", wire_version=999))
    assert reply["type"] == "reject"
    assert "wire_version" in reply["reason"]
    assert coordinator.worker_count == 0


def test_handshake_rejects_protocol_version_mismatch(coordinator):
    reply = _handshake(coordinator, remote.hello_frame(
        pid=1, host="test", protocol_version=999))
    assert reply["type"] == "reject"
    assert "protocol_version" in reply["reason"]


def test_handshake_rejects_non_hello(coordinator):
    reply = _handshake(coordinator, {"type": "task"})
    assert reply["type"] == "reject"


def test_handshake_rejects_stale_policy_signature(coordinator):
    host, port = remote.parse_address(coordinator.address)
    sock = socket.create_connection((host, port), timeout=10)
    try:
        sock.settimeout(10)
        remote.send_frame(sock, remote.hello_frame(pid=1, host="test"))
        config_frame = remote.recv_frame(sock)
        assert config_frame["type"] == "config"
        # a stale worker build would re-derive a different signature
        remote.send_frame(sock, {
            "type": "ready",
            "policy_signature": "stale-signature",
            "kb_content_hash": config_frame["kb_content_hash"]})
        reply = remote.recv_frame(sock)
        assert reply["type"] == "reject"
        assert "signature" in reply["reason"]
    finally:
        sock.close()
    assert coordinator.worker_count == 0
    assert coordinator.workers_rejected >= 1


# ----------------------------------------------------------------------
# graceful drain: queued work completes before workers shut down
# ----------------------------------------------------------------------

def test_drain_completes_queued_work():
    cfg = ForgeConfig()
    pipeline = ForgePipeline.from_config(cfg)
    coord = FleetCoordinator(pipeline, cfg, spawn_workers=1).start()
    procs = list(coord._procs)
    try:
        coord.wait_for_workers(1, timeout=120)
        # more tasks than workers: with one worker, tasks queue up
        wire = job_codec.encode_job(_job("gemm_bias_gelu"))
        tasks = [("keys", i, wire) for i in range(4)]
        out = {}
        runner = threading.Thread(
            target=lambda: out.update(coord.run_tasks(tasks)))
        runner.start()
        while coord._run_id == 0:     # run definitely underway
            time.sleep(0.01)
        coord.drain(timeout=60)       # blocks until the run finishes
        runner.join(timeout=60)
        assert not runner.is_alive()
        assert sorted(out) == [0, 1, 2, 3]
        # workers drained out with exit code 0, none were lost
        assert [p.wait(timeout=30) for p in procs] == [0]
        assert coord.workers_lost == 0
        assert coord.worker_count == 0
    finally:
        coord.close(graceful=False)
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()


def test_closed_coordinator_rejects_runs():
    cfg = ForgeConfig()
    coord = FleetCoordinator(ForgePipeline.from_config(cfg), cfg).start()
    coord.close()
    from repro.core.fleet import FleetError
    with pytest.raises(FleetError, match="closed"):
        coord.run_tasks([("keys", 0, {})])


# ----------------------------------------------------------------------
# unified observer protocol: adapters are event-for-event equivalent
# ----------------------------------------------------------------------

def test_observer_adapter_equivalence():
    """Legacy observers (old method names), new-protocol observers, the
    deprecated on_stage kwarg, and CallbackObserver all see identical
    event sequences from one run."""
    legacy_events, new_events, kw_stages, cb_stages = [], [], [], []

    class Legacy:  # old duck-typed surface, no base class
        def on_stage_complete(self, job_name, record):
            legacy_events.append(("stage", job_name, record.stage))

        def on_job_complete(self, result):
            legacy_events.append(("job", result.job.name))

        def on_transfer(self, result):
            legacy_events.append(("transfer", result.job.name))

    class New(ForgeObserver):
        def on_stage(self, e):
            new_events.append(("stage", e.job_name, e.record.stage))

        def on_job(self, e):
            new_events.append(("job", e.result.job.name))

        def on_seed_transfer(self, e):
            new_events.append(("transfer", e.result.job.name))

    forge = Forge(ForgeConfig(execution_backend="serial"),
                  observers=[Legacy(), New()])
    report = forge.optimize_batch(
        [_job("gemm_bias_gelu"), _twin_job()],
        on_stage=lambda i, n, r: kw_stages.append((i, n, r.stage)),
        observer=CallbackObserver(
            on_stage_indexed=lambda i, n, r: cb_stages.append((i, n, r.stage))))
    forge.close()

    assert legacy_events and legacy_events == new_events
    assert kw_stages and kw_stages == cb_stages
    assert {i for i, _, _ in cb_stages} == {0, 1}
    if report.transfers:
        assert ("transfer", "gemm_bias_gelu_twin") in legacy_events
    # ordering contract: all stage events for a job precede its job event
    for name in ("gemm_bias_gelu", "gemm_bias_gelu_twin"):
        job_at = legacy_events.index(("job", name))
        assert all(legacy_events.index(e) < job_at
                   for e in legacy_events
                   if e[0] == "stage" and e[1] == name)


def test_as_observer_passthrough_and_mixed():
    from repro.core.observers import (FanOutObserver, JobEvent, StageEvent,
                                      as_observer)
    assert as_observer(None) is None
    fan = FanOutObserver()
    assert as_observer(fan) is fan

    calls = []

    class Mixed(ForgeObserver):  # new-style stage, legacy job
        def on_stage(self, e):
            calls.append(("new-stage", e.job_name))

        def on_job_complete(self, result):
            calls.append(("old-job", result))

    obs = as_observer(Mixed())
    obs.on_stage(StageEvent("k", record=None))

    class R:
        pass
    obs.on_job(JobEvent(R()))
    assert [c[0] for c in calls] == ["new-stage", "old-job"]


# ----------------------------------------------------------------------
# wire versioning (codec level)
# ----------------------------------------------------------------------

def test_wire_version_rejected_by_decoders():
    wire = job_codec.encode_job(_job("gemm_bias_gelu"))
    assert wire["wire_version"] == job_codec.WIRE_VERSION
    wire["wire_version"] = 999
    with pytest.raises(WireVersionError, match="999"):
        job_codec.decode_job(wire)
    # typed subclass: HTTP maps WireDecodeError -> 400, version mismatch
    # rides the same path
    assert issubclass(WireVersionError, WireDecodeError)
    try:
        job_codec.decode_job(wire)
    except WireVersionError as exc:
        assert exc.version == 999
        assert "1" in str(exc)


def test_legacy_envelopes_still_decode():
    """Envelopes without a wire_version (hand-built fixtures, pre-version
    stores) pass through; only an *unknown declared* version rejects."""
    wire = job_codec.encode_job(_job("gemm_bias_gelu"))
    del wire["wire_version"]
    job = job_codec.decode_job(wire)
    assert job.name == "gemm_bias_gelu"


# ----------------------------------------------------------------------
# client: deterministic backoff + typed stream interruption
# ----------------------------------------------------------------------

def test_poll_backoff_deterministic_and_capped():
    a = [_poll_backoff("job-1", n) for n in range(12)]
    b = [_poll_backoff("job-1", n) for n in range(12)]
    assert a == b                         # no random: reproducible
    assert a != [_poll_backoff("job-2", n) for n in range(12)]  # jittered
    for n, v in enumerate(a):
        raw = min(2.0, 0.05 * 2 ** n)
        assert raw * 0.5 <= v < raw       # jitter range
    assert max(a) < 2.0                   # capped


def test_stream_interrupted_is_typed():
    assert issubclass(StreamInterrupted, Exception)
    exc = StreamInterrupted("j-1", 3)
    assert exc.job_id == "j-1"
    assert exc.events_seen == 3
    assert "j-1" in str(exc)


# ----------------------------------------------------------------------
# durability: fault-plan worker kill + auto-respawn, coordinator journal
# recovery, worker --reconnect
# ----------------------------------------------------------------------


def test_fault_plan_kill_respawn_byte_equivalent(serial_report):
    """FaultPlan generalization of --die-after, threaded through
    ForgeConfig.fault_spec: spawned worker 0 dies on its first job; the
    coordinator re-dispatches AND auto-respawns a replacement (without
    the fault plan — it must not re-die), and the report stays
    byte-equivalent to the serial reference."""
    from repro.core.faults import FaultPlan
    plan = FaultPlan(kill_worker_after_jobs=0, worker_index=0)
    cfg = ForgeConfig(execution_backend="remote", workers=2,
                      fleet_heartbeat_s=0.5, fleet_heartbeat_timeout_s=3.0,
                      fault_spec=plan.to_json(), fleet_max_respawns=2)
    forge = Forge(cfg)
    try:
        report = forge.optimize_batch(_jobs())
        fleet = forge.engine._get_executor().fleet
        deadline = time.monotonic() + 30
        while (fleet.workers_respawned < 1
               and time.monotonic() < deadline):
            time.sleep(0.05)
        tel = fleet.telemetry()
        assert tel["workers_lost"] >= 1
        assert tel["tasks_redispatched"] >= 1
        assert tel["workers_respawned"] >= 1
        assert _comparable(report) == _comparable(serial_report)
    finally:
        forge.close()


def test_drop_frame_fault_severs_and_redispatches():
    """drop_frame_after: the fault worker severs its socket instead of
    sending event frame 1 — the coordinator sees EOF and re-dispatches,
    and every keys task still completes."""
    from repro.core.faults import FaultPlan
    cfg = ForgeConfig()
    pipeline = ForgePipeline.from_config(cfg)
    plan = FaultPlan(drop_frame_after=1, worker_index=0)
    coord = FleetCoordinator(pipeline, cfg, spawn_workers=2,
                             fault_plan=plan).start()
    try:
        coord.wait_for_workers(2, timeout=120)
        wires = [job_codec.encode_job(_job(n))
                 for n in sorted(SPECS)[:3]]
        out = coord.run_tasks([("keys", i, w) for i, w in enumerate(wires)])
        assert sorted(out) == [0, 1, 2]
        # the fault fires inside the worker subprocess (its own FaultPlan
        # copy), so the coordinator-side evidence is the loss+redispatch
        assert coord.workers_lost >= 1
        assert coord.tasks_redispatched >= 1
    finally:
        coord.close(graceful=True)


def test_coordinator_journal_recovery_resumes_pending(tmp_path):
    """Crash the coordinator mid-wave (after its first journaled
    completion): a successor opening the same journal recovers the
    dispatched-but-incomplete tasks and resume_pending() re-runs exactly
    those."""
    from repro.core.faults import FaultPlan, InjectedCrash
    cfg = ForgeConfig()
    pipeline = ForgePipeline.from_config(cfg)
    journal = str(tmp_path / "fleet.wal")
    plan = FaultPlan(crash_coordinator_after_completions=1)
    coord = FleetCoordinator(pipeline, cfg, spawn_workers=2,
                             fault_plan=plan, journal_path=journal).start()
    wires = [job_codec.encode_job(_job(n)) for n in sorted(SPECS)[:3]]
    tasks = [("keys", i, w) for i, w in enumerate(wires)]
    try:
        coord.wait_for_workers(2, timeout=120)
        with pytest.raises(InjectedCrash):
            coord.run_tasks(tasks)
        assert plan.fired.get("crash_coordinator") == 1
    finally:
        coord.close(graceful=False)

    coord2 = FleetCoordinator(pipeline, cfg, spawn_workers=2,
                              journal_path=journal).start()
    try:
        # both workers held a dispatched task; one completion was
        # journaled before the crash — the other must be recovered
        assert coord2.tasks_recovered >= 1
        coord2.wait_for_workers(1, timeout=120)
        recovered = coord2.resume_pending()
        assert len(recovered) == coord2.tasks_recovered
        assert set(recovered) <= {0, 1, 2}
        assert coord2.resume_pending() == {}    # one-shot
        # resumed payloads are real keys results, not journal echoes
        for payload in recovered.values():
            assert len(tuple(payload)) >= 2
    finally:
        coord2.close(graceful=True)


def test_worker_reconnect_retries_transport_loss_only():
    """--reconnect N retries connection loss (exit 4) with deterministic
    backoff, N times, then gives up with the same exit code."""
    # grab a port nothing listens on
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(SRC) + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else str(SRC))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.remote_worker",
         "--connect", f"127.0.0.1:{port}", "--reconnect", "2"],
        env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 4
    assert proc.stderr.count("reconnect") == 2
