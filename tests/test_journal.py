"""Journal durability contract: tuple-fidelity round-trips, torn-tail
truncation, typed corruption refusal, atomic compaction, and the
FaultPlan torn-write injection that drives the chaos gate."""

import os
import struct

import pytest

from repro.core.faults import FaultPlan, InjectedCrash, deterministic_backoff
from repro.core.journal import (Journal, JournalCorruption, JournalError,
                                JOURNAL_MAGIC, complete_record,
                                dispatch_record, submit_record,
                                terminal_record, wave_record)

_REC = struct.Struct(">II")
_HEADER_SIZE = struct.calcsize(">8sII")


def _records(n=4):
    """A representative mix: nested dicts, tuples (fleet task shape),
    None, floats — everything the tuple-tagging codec must preserve."""
    return [
        submit_record("job-%06d" % i, {"name": f"k{i}", "v": 3},
                      client=f"tenant-{i % 2}", priority=i, seq=i,
                      created_s=1000.0 + i,
                      attached_to=None if i % 2 else "job-000000")
        for i in range(n)
    ] + [dispatch_record(7, ("job", 2, {"w": 1}, "ek", "fk", None,
                             None, [1, 2], None)),
         wave_record(7, 3), complete_record(7, 2),
         terminal_record("job-000001", "done", report={"jobs": []},
                         finished_s=2000.0)]


def test_roundtrip_preserves_tuples(tmp_path):
    path = str(tmp_path / "a.wal")
    j = Journal(path)
    for rec in _records():
        j.append(rec)
    j.close()

    j2 = Journal(path)
    assert j2.records == _records()
    # tuple fidelity: the fleet task tuple came back a tuple, not a list
    task = j2.records[4]["task"]
    assert isinstance(task, tuple) and task[0] == "job"
    assert isinstance(task[7], list)
    assert j2.recovered == len(_records()) and not j2.truncated_tail
    j2.close()


def test_torn_final_record_truncated_and_tolerated(tmp_path):
    path = str(tmp_path / "torn.wal")
    j = Journal(path)
    for rec in _records(2):
        j.append(rec)
    j.close()
    intact_size = os.path.getsize(path)
    # simulate power loss mid-append: half a record at the tail
    with open(path, "ab") as fh:
        fh.write(_REC.pack(1000, 0) + b"x" * 7)

    j2 = Journal(path)
    assert j2.truncated_tail is True
    assert j2.records == _records(2)        # only the torn append lost
    assert os.path.getsize(path) == intact_size   # file healed in place
    j2.append({"kind": "after", "ok": True})      # and appendable again
    j2.close()
    assert Journal.load(path)[-1] == {"kind": "after", "ok": True}


def test_final_record_bad_crc_is_torn_tail(tmp_path):
    """A full-length final record with a CRC mismatch is still a torn
    tail (the bytes landed, the fsync didn't) — truncated, not fatal."""
    path = str(tmp_path / "crc_tail.wal")
    j = Journal(path)
    for rec in _records(3):
        j.append(rec)
    j.close()
    with open(path, "ab") as fh:
        fh.write(_REC.pack(4, 12345) + b"hmm!")     # wrong crc, full length

    j2 = Journal(path)
    assert j2.truncated_tail is True
    assert j2.records == _records(3)
    j2.close()


def test_mid_file_crc_corruption_raises_typed_error(tmp_path):
    path = str(tmp_path / "rot.wal")
    j = Journal(path)
    for rec in _records():
        j.append(rec)
    j.close()
    # flip one payload byte of the FIRST record: committed records follow
    # it, so this is bit rot, never a torn tail
    with open(path, "r+b") as fh:
        fh.seek(_HEADER_SIZE + _REC.size + 2)
        byte = fh.read(1)
        fh.seek(-1, os.SEEK_CUR)
        fh.write(bytes([byte[0] ^ 0xFF]))

    with pytest.raises(JournalCorruption):
        Journal(path)
    with pytest.raises(JournalCorruption):
        Journal.load(path)


def test_bad_magic_raises_journal_error(tmp_path):
    path = str(tmp_path / "not_a_journal.wal")
    with open(path, "wb") as fh:
        fh.write(b"NOTMAGIC" + b"\0" * 24)
    with pytest.raises(JournalError):
        Journal(path)


def test_unsupported_version_raises(tmp_path):
    path = str(tmp_path / "future.wal")
    with open(path, "wb") as fh:
        fh.write(struct.pack(">8sII", JOURNAL_MAGIC, 999, 0))
    with pytest.raises(JournalError):
        Journal(path)


def test_compaction_preserves_byte_equivalent_replay(tmp_path):
    """Compacting to the live records must replay identically to the
    append-built journal — byte-for-byte identical files, in fact, since
    both are header + the same canonical encodings."""
    path_a = str(tmp_path / "appended.wal")
    path_b = str(tmp_path / "compacted.wal")
    recs = _records()
    ja = Journal(path_a)
    for rec in recs:
        ja.append(rec)
    ja.close()

    jb = Journal(path_b)
    jb.append({"kind": "noise", "n": 1})        # superseded history
    jb.append({"kind": "noise", "n": 2})
    jb.compact(recs)
    assert jb.records == recs                   # live view swapped too
    jb.append({"kind": "post", "p": 1})         # handle survives compact
    jb.close()

    with open(path_a, "rb") as fh:
        bytes_a = fh.read()
    with open(path_b, "rb") as fh:
        bytes_b = fh.read()
    assert bytes_b.startswith(bytes_a)          # same prefix, byte-exact
    assert Journal.load(path_b) == recs + [{"kind": "post", "p": 1}]
    assert not os.path.exists(path_b + ".tmp")  # no debris


def test_fault_plan_torn_write_injection(tmp_path):
    path = str(tmp_path / "inject.wal")
    plan = FaultPlan(torn_write_record=3)
    j = Journal(path, fault_plan=plan)
    j.append({"kind": "a", "n": 1})
    j.append({"kind": "b", "n": 2})
    with pytest.raises(InjectedCrash):
        j.append({"kind": "c", "n": 3})         # torn mid-write
    j.close()
    assert plan.fired.get("torn_write") == 1

    # recovery: the torn third append is truncated away, first two intact
    j2 = Journal(path)
    assert j2.truncated_tail is True
    assert j2.records == [{"kind": "a", "n": 1}, {"kind": "b", "n": 2}]
    j2.close()


def test_torn_header_means_fresh_journal(tmp_path):
    """A crash during file creation (partial header, nothing committed)
    starts clean instead of refusing."""
    path = str(tmp_path / "stub.wal")
    with open(path, "wb") as fh:
        fh.write(JOURNAL_MAGIC[:5])
    j = Journal(path)
    assert j.records == [] and j.truncated_tail is True
    j.append({"kind": "first"})
    j.close()
    assert Journal.load(path) == [{"kind": "first"}]


def test_load_is_readonly(tmp_path):
    """Journal.load never truncates — safe on a file another process
    owns, even with a torn tail present."""
    path = str(tmp_path / "ro.wal")
    j = Journal(path)
    j.append({"kind": "x"})
    j.close()
    with open(path, "ab") as fh:
        fh.write(b"\x00\x01\x02")               # torn tail
    size_before = os.path.getsize(path)
    assert Journal.load(path) == [{"kind": "x"}]
    assert os.path.getsize(path) == size_before


def test_sync_false_appends_still_replay(tmp_path):
    path = str(tmp_path / "nosync.wal")
    j = Journal(path)
    j.append(complete_record(1, 0), sync=False)
    j.append(complete_record(1, 1), sync=False)
    j.close()
    assert Journal.load(path) == [complete_record(1, 0),
                                  complete_record(1, 1)]


def test_fault_plan_json_roundtrip():
    plan = FaultPlan(seed=7, kill_worker_after_jobs=2, worker_index=1,
                     crash_dispatcher_wave=3,
                     crash_dispatcher_point="after-journal",
                     torn_write_record=5)
    again = FaultPlan.from_json(plan.to_json())
    assert again.to_dict() == plan.to_dict()
    with pytest.raises(ValueError):
        FaultPlan.from_json('{"no_such_field": 1}')
    with pytest.raises(ValueError):
        FaultPlan(crash_dispatcher_point="sideways")


def test_deterministic_backoff_shared_schedule():
    """Reproducible, capped, and desynchronized across keys — the one
    schedule every retry loop in the stack now shares."""
    a = [deterministic_backoff("k1", n) for n in range(12)]
    assert a == [deterministic_backoff("k1", n) for n in range(12)]
    assert all(0 < s <= 2.0 for s in a)
    assert a[6:] != [deterministic_backoff("k2", n) for n in range(12)][6:]
