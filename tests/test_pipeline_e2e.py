"""End-to-end pipeline behavior on suite problems + ablations."""

import pytest

from repro.aibench import build_program, load_specs
from repro.core.pipeline import ForgePipeline
from repro.ir.cost import CostModel

CM = CostModel()


def _run(name, pipeline=None, **kw):
    spec = next(s for s in load_specs() if s.name == name)
    pipe = pipeline or ForgePipeline(**kw)
    return pipe.optimize(
        spec.name,
        build_program(spec.builder, spec.dims("ci"), "naive", meta=spec.meta),
        build_program(spec.builder, spec.dims("bench"), "naive", meta=spec.meta),
        tags=tuple(spec.tags), target_dtype=spec.target_dtype,
        rtol=spec.rtol, atol=spec.atol, meta=spec.meta)


def test_discovery_eliminates_gemm():
    res = _run("gemm_divide_sum")
    assert res.speedup > 5
    stages = {r.stage: r for r in res.stage_records}
    assert stages["algorithmic"].improved
    # the optimized graph has no full-size GEMM left
    mms = [n for n in res.bench_program.graph.toposorted() if n.op == "matmul"]
    assert all(1 in n.shape for n in mms)


def test_reduction_fusion_path():
    res = _run("gemm_max_subtract_gelu")
    assert res.speedup > 3
    fused = [g for g in res.bench_program.schedule.groups
             if len(g.nodes) > 1 and g.impl == "pallas_blockspec"]
    assert fused, "expected a fused blockspec kernel"


def test_dtype_pipeline_f64():
    res = _run("gemm_f64_sigmoid")
    assert all(n.dtype != "float64"
               for n in res.bench_program.graph.toposorted())
    assert res.speedup > 2


def test_never_degrade_overall():
    for name in ("convt3d_silu", "bmm_instnorm_sum_residual"):
        res = _run(name)
        assert res.optimized_time <= res.original_time * 1.0001


def test_ablation_no_pipeline_stages():
    """Disabling restructuring stages loses the large wins (paper's stage
    attribution argument)."""
    full = _run("gemm_divide_sum")
    crippled = _run("gemm_divide_sum",
                    pipeline=ForgePipeline(
                        stages_enabled=["dtype_fix", "gpu_specific",
                                        "autotuning"]))
    assert full.speedup > crippled.speedup


def test_best_of_k_at_least_as_good():
    r1 = _run("gemm_bias_gelu")
    rk = _run("gemm_bias_gelu", pipeline=ForgePipeline(best_of_k=2))
    assert rk.optimized_time <= r1.optimized_time * 1.05


def test_stage_log_complete():
    res = _run("matmul_t_gelu")
    assert res.stage_records, "stages must be recorded"
    for r in res.stage_records:
        assert r.stage in ("algorithmic", "discovery", "dtype_fix", "fusion",
                           "memory_access", "block_pointers",
                           "persistent_kernel", "gpu_specific", "autotuning")
