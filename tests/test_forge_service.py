"""Forge service: queue priority, cross-request dedup, per-client rate
limiting, SSE stage streaming, wire hardening (400s), and graceful drain.

The module-scoped server runs one real optimization over HTTP and the
byte-equivalence test compares its report against a direct
``Forge.optimize`` call with the same config — the service must be a
transparent remote facade, not a lossy summary of one.
"""

import json
import threading

import pytest

from repro.aibench import build_program, load_specs
from repro.core.job_codec import WireDecodeError, decode_job, encode_job
from repro.forge import Forge, ForgeConfig, KernelJob
from repro.serve import (ForgeClient, ForgeService, ForgeServiceServer,
                         QueueFull, RateLimited, ServiceClosed,
                         ServiceConfig, ServiceError, UnknownJob)

SPECS = {s.name: s for s in load_specs()}

# cheap policy for service tests: one CoVeR iteration per stage — the
# service semantics under test are independent of search depth
CONFIG = ForgeConfig(max_iterations=1)


def _job(name):
    s = SPECS[name]
    return KernelJob(s.name,
                     build_program(s.builder, s.dims("ci"), "naive",
                                   meta=s.meta),
                     build_program(s.builder, s.dims("bench"), "naive",
                                   meta=s.meta),
                     tags=tuple(s.tags), target_dtype=s.target_dtype,
                     rtol=s.rtol, atol=s.atol, meta=dict(s.meta))


_NAMES = sorted(SPECS)


def _canon(payload):
    return json.dumps(payload, sort_keys=True)


# ---------------------------------------------------------------------------
# module server: one kernel submitted twice (dedup) + the direct reference
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    service = ForgeService(CONFIG,
                           service_config=ServiceConfig(wave_size=2))
    server = ForgeServiceServer(("127.0.0.1", 0), service)
    server.serve_background()
    client = ForgeClient(server.url, api_key="tenant-a")
    client.wait_ready(timeout=30)
    r1 = client.submit(_job(_NAMES[0]))
    r2 = client.submit(_job(_NAMES[0]))      # exact duplicate, in flight
    s1 = client.wait(r1["job_id"], timeout=300)
    s2 = client.wait(r2["job_id"], timeout=300)
    yield {"service": service, "server": server, "client": client,
           "receipts": (r1, r2), "statuses": (s1, s2)}
    server.shutdown_all(drain=True)


def test_submit_receipt_shape(served):
    r1, r2 = served["receipts"]
    assert r1["deduped"] is False and r1["queue_position"] == 1
    assert r1["job_id"] != r2["job_id"]


def test_cross_request_dedup_attaches_and_runs_engine_once(served):
    r1, r2 = served["receipts"]
    s1, s2 = served["statuses"]
    # the second submit attached to the first job instead of queueing
    assert r2["deduped"] is True and r2["attached_to"] == r1["job_id"]
    assert s2["deduped"] is True
    # proven by engine stats: ONE engine execution served both requests
    assert served["service"].forge.stats.jobs == 1
    # ...and both clients got identical reports
    assert _canon(s1["report"]) == _canon(s2["report"])


def test_report_byte_equivalent_to_direct_forge(served):
    s1, _ = served["statuses"]
    with Forge(CONFIG) as forge:
        direct = forge.optimize(_job(_NAMES[0])).as_dict()
    assert _canon(s1["report"]) == _canon(direct)


def test_sse_event_count_matches_stage_records(served):
    r1, r2 = served["receipts"]
    s1, _ = served["statuses"]
    stage_dicts = s1["report"]["jobs"][0]["stages"]
    assert stage_dicts, "expected at least one stage record"
    for rid in (r1["job_id"], r2["job_id"]):    # attached job mirrors too
        events = list(served["client"].events(rid))
        stages = [d for e, d in events if e == "stage"]
        assert len(stages) == len(stage_dicts)
        assert stages == stage_dicts            # same records, same order
        assert events[-1][0] == "done"
        assert events[-1][1]["state"] == "done"


def test_status_includes_queue_metadata(served):
    s1, _ = served["statuses"]
    assert s1["state"] == "done"
    assert s1["name"] == _NAMES[0]
    assert s1["client"] == "tenant-a"
    assert s1["events"] == len(s1["report"]["jobs"][0]["stages"])


def test_stats_endpoint_shows_multitenant_counters(served):
    stats = served["client"].stats()
    assert stats["engine"]["jobs"] == 1
    assert stats["jobs_by_state"]["done"] == 2
    c = stats["clients"]["tenant-a"]
    assert c["submitted"] == 2 and c["deduped"] == 1 and c["completed"] == 2
    assert stats["store"]["entries"] == 1
    assert stats["accepting"] is True


def test_healthz(served):
    assert served["client"].healthz() == {"ok": True, "accepting": True}


def test_unknown_job_404(served):
    with pytest.raises(ServiceError) as ei:
        served["client"].status("job-999999")
    assert ei.value.status == 404
    with pytest.raises(ServiceError) as ei:
        list(served["client"].events("job-999999"))
    assert ei.value.status == 404


def test_unknown_route_404(served):
    with pytest.raises(ServiceError) as ei:
        served["client"]._request("GET", "/v2/nope")
    assert ei.value.status == 404


# ---------------------------------------------------------------------------
# wire hardening: malformed payloads are 400s, never stack traces
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def paused():
    """Server whose dispatcher never starts: queue/reject semantics only,
    zero optimization cost."""
    service = ForgeService(
        CONFIG, autostart=False,
        service_config=ServiceConfig(rate_per_sec=0.2, burst=1,
                                     max_queue_depth=4))
    server = ForgeServiceServer(("127.0.0.1", 0), service)
    server.serve_background()
    yield ForgeClient(server.url)
    server.shutdown()
    server.server_close()
    service.forge.close()


@pytest.mark.parametrize("wire", [
    {},                                           # missing everything
    {"name": "x"},                                # no programs
    {"name": "x", "ci_program": 7, "bench_program": 7},   # wrong types
    {"name": "x", "ci_program": {"graph": {"nodes": "nope"}},
     "bench_program": {}},                        # nodes not a list
])
def test_malformed_job_wire_is_400(paused, wire):
    with pytest.raises(ServiceError) as ei:
        paused.submit_wire(wire)
    assert ei.value.status == 400
    assert "malformed" in str(ei.value) or "wire" in str(ei.value)


def test_malformed_envelope_is_400(paused):
    for body in [None, {"nope": 1}, {"job": "not-a-dict"},
                 {"job": encode_job(_job(_NAMES[1])), "priority": "high"}]:
        with pytest.raises(ServiceError) as ei:
            paused._request("POST", "/v1/jobs", body=body)
        assert ei.value.status == 400


def test_decode_errors_are_typed():
    # the codec satellite: every malformed decode is WireDecodeError (a
    # ValueError), never a raw KeyError/TypeError leaking wire internals
    for wire in [{}, {"name": 1, "ci_program": [], "bench_program": {}},
                 {"name": "x", "ci_program": {"graph": {"nodes": [42]}},
                  "bench_program": {}}]:
        with pytest.raises(WireDecodeError) as ei:
            decode_job(wire)
        assert isinstance(ei.value, ValueError)
        assert "malformed" in str(ei.value)


def test_wire_roundtrip_still_exact():
    job = _job(_NAMES[1])
    again = decode_job(encode_job(job))
    assert encode_job(again) == encode_job(job)


# ---------------------------------------------------------------------------
# rate limiting
# ---------------------------------------------------------------------------


def test_rate_limit_429_with_retry_after(paused):
    wire = encode_job(_job(_NAMES[1]))
    ok = paused._request("POST", "/v1/jobs",
                         body={"job": wire})            # anonymous bucket
    assert ok["state"] == "queued"
    limited = ForgeClient(f"http://{paused.host}:{paused.port}",
                          api_key="tenant-burst1")
    limited.submit_wire(wire)                           # burst=1: takes it
    with pytest.raises(ServiceError) as ei:
        limited.submit_wire(wire)
    assert ei.value.status == 429
    assert ei.value.retry_after_s and ei.value.retry_after_s > 0
    # buckets are per client token: a different tenant still gets through
    other = ForgeClient(f"http://{paused.host}:{paused.port}",
                        api_key="tenant-fresh")
    assert other.submit_wire(wire)["job_id"]


def test_queue_full_rejects_but_duplicates_attach():
    svc = ForgeService(CONFIG, autostart=False,
                       service_config=ServiceConfig(max_queue_depth=1))
    try:
        svc.submit_job(_job(_NAMES[2]))
        with pytest.raises(QueueFull):
            svc.submit_job(_job(_NAMES[3]))
        # a duplicate of an in-flight job attaches even when the queue is
        # full — attaching adds no engine work
        receipt = svc.submit_job(_job(_NAMES[2]))
        assert receipt["deduped"] is True
    finally:
        svc.forge.close()


# ---------------------------------------------------------------------------
# priority queue + graceful shutdown (in-process: queue mechanics only)
# ---------------------------------------------------------------------------


def test_priority_ordering_drains_high_first():
    svc = ForgeService(CONFIG, autostart=False,
                       service_config=ServiceConfig(wave_size=1))
    low = svc.submit_job(_job(_NAMES[0]), priority=0)
    high = svc.submit_job(_job(_NAMES[1]), priority=5)
    mid = svc.submit_job(_job(_NAMES[2]), priority=5)
    assert svc.status(high["job_id"])["queue_position"] == 1
    assert svc.status(mid["job_id"])["queue_position"] == 2   # FIFO tie
    assert svc.status(low["job_id"])["queue_position"] == 3
    svc.start()
    done = {jid: svc.wait(jid, timeout=300)
            for jid in (low["job_id"], high["job_id"], mid["job_id"])}
    assert all(d["state"] == "done" for d in done.values())
    starts = {jid: d["started_s"] for jid, d in done.items()}
    # wave_size=1: strictly sequential waves, so start times order the
    # actual dispatch — high priority first, FIFO within a level, low last
    assert starts[high["job_id"]] < starts[mid["job_id"]]
    assert starts[mid["job_id"]] < starts[low["job_id"]]
    svc.shutdown(drain=True)


def test_graceful_shutdown_drains_queue():
    svc = ForgeService(CONFIG, autostart=False,
                       service_config=ServiceConfig(wave_size=2))
    receipt = svc.submit_job(_job(_NAMES[3]))
    svc.start()
    svc.shutdown(drain=True)        # blocks until the queue is empty
    status = svc.status(receipt["job_id"])
    assert status["state"] == "done"
    assert status["report"]["jobs"][0]["name"] == _NAMES[3]
    with pytest.raises(ServiceClosed):
        svc.submit_job(_job(_NAMES[3]))


def test_shutdown_without_drain_cancels_queued():
    svc = ForgeService(CONFIG, autostart=False)
    receipt = svc.submit_job(_job(_NAMES[4]))
    svc.shutdown(drain=False)
    assert svc.status(receipt["job_id"])["state"] == "cancelled"


def test_wait_unknown_and_timeout():
    svc = ForgeService(CONFIG, autostart=False)
    with pytest.raises(UnknownJob):
        svc.wait("job-404")
    receipt = svc.submit_job(_job(_NAMES[4]))
    with pytest.raises(TimeoutError):
        svc.wait(receipt["job_id"], timeout=0.05)   # dispatcher is off
    svc.shutdown(drain=False)


# ---------------------------------------------------------------------------
# package surface + config validation
# ---------------------------------------------------------------------------


def test_serve_package_reexports():
    import repro.serve as serve
    for name in ("ForgeService", "ServiceConfig", "ForgeClient",
                 "ForgeServiceServer", "RateLimited", "ServiceClosed",
                 "QueueFull", "UnknownJob", "ServiceError", "Request",
                 "ServeEngine"):
        assert name in serve.__all__
        assert getattr(serve, name) is not None


def test_serve_engine_queue_is_deque():
    import collections
    import inspect

    from repro.serve import engine
    # the admission queue satellite: deque + popleft, not list.pop(0)
    src = inspect.getsource(engine.ServeEngine)
    assert "collections.deque()" in src
    assert "self.queue.popleft()" in src
    assert "self.queue.pop(0)" not in src
    assert engine.collections is collections


def test_service_config_validation():
    with pytest.raises(ValueError):
        ServiceConfig(wave_size=0)
    with pytest.raises(ValueError):
        ServiceConfig(rate_per_sec=-1)
    with pytest.raises(ValueError):
        ServiceConfig(burst=0)
    with pytest.raises(ValueError):
        ServiceConfig(max_queue_depth=-1)


def test_rate_limited_exception_carries_retry_hint():
    svc = ForgeService(CONFIG, autostart=False,
                       service_config=ServiceConfig(rate_per_sec=0.1,
                                                    burst=1))
    svc.submit_job(_job(_NAMES[5]), client="t")
    with pytest.raises(RateLimited) as ei:
        svc.submit_job(_job(_NAMES[5]), client="t")
    assert ei.value.client == "t"
    assert 0 < ei.value.retry_after_s <= 10.0
    stats = svc.stats()
    assert stats["clients"]["t"]["rate_limited"] == 1
    svc.shutdown(drain=False)


# ---------------------------------------------------------------------------
# client 429 retry (opt-in) + journal-aware healthz
# ---------------------------------------------------------------------------


def test_client_retry_on_rate_limit_honors_retry_after():
    service = ForgeService(
        CONFIG, autostart=False,
        service_config=ServiceConfig(rate_per_sec=5.0, burst=1))
    server = ForgeServiceServer(("127.0.0.1", 0), service)
    server.serve_background()
    try:
        wire = encode_job(_job(_NAMES[2]))
        # default client: no retry — the second submit raises 429
        plain = ForgeClient(server.url, api_key="bucket-a")
        plain.submit_wire(wire)
        with pytest.raises(ServiceError) as ei:
            plain.submit_wire(wire)
        assert ei.value.status == 429

        # opt-in client: sleeps out the server's Retry-After and succeeds
        patient = ForgeClient(server.url, api_key="bucket-b",
                              retry_on_rate_limit=True)
        patient.submit_wire(wire)
        receipt = patient.submit_wire(wire)     # 429 -> wait -> attach
        assert receipt["job_id"]

        # bounded: zero retries allowed means the 429 surfaces unchanged
        bounded = ForgeClient(server.url, api_key="bucket-c",
                              retry_on_rate_limit=True,
                              rate_limit_retries=0)
        bounded.submit_wire(wire)
        with pytest.raises(ServiceError) as ei:
            bounded.submit_wire(wire)
        assert ei.value.status == 429
    finally:
        server.shutdown()
        server.server_close()
        service.forge.close()


def test_healthz_reports_journal_when_configured(tmp_path):
    service = ForgeService(CONFIG, autostart=False,
                           journal_path=str(tmp_path / "svc.wal"))
    server = ForgeServiceServer(("127.0.0.1", 0), service)
    server.serve_background()
    try:
        client = ForgeClient(server.url)
        health = client.healthz()
        assert health["ok"] is True and health["accepting"] is True
        assert health["journal"]["path"].endswith("svc.wal")
        assert health["journal"]["jobs_requeued"] == 0
        stats = client.stats()
        assert stats["journal"]["records"] == 0
    finally:
        server.shutdown()
        server.server_close()
        service.forge.close()
        service._journal.close()


def test_status_dict_has_monotonic_durations(served):
    s1, _ = served["statuses"]
    assert s1["wait_s"] is not None and s1["wait_s"] >= 0.0
    assert s1["run_s"] is not None and s1["run_s"] > 0.0
