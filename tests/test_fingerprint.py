"""Structural fingerprints: rename-invariance, schedule/tolerance
sensitivity, collision behavior."""

import pytest

from repro.aibench import build_program, load_specs
from repro.ir import GraphBuilder
from repro.ir.fingerprint import (canonical_name_map, fingerprint_job,
                                  fingerprint_program, program_canonical)
from repro.ir.cost import graph_flops
from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule


def _gemm_program(m=64, n=64, k=32, names=("x", "w", "mm", "act")):
    b = GraphBuilder("p")
    x = b.input((m, k), name=names[0])
    w = b.param((k, n), name=names[1])
    mm = b.matmul(x, w, name=names[2])
    g = b.done(b.gelu(mm, name=names[3]))
    return KernelProgram("p", g, eager_schedule(g),
                         original_flops=graph_flops(g))


def test_rename_invariance():
    """Same graph under node renaming -> same key."""
    a = _gemm_program()
    b = _gemm_program(names=("inp", "weights", "prod", "activation"))
    assert fingerprint_program(a) == fingerprint_program(b)


def test_shape_changes_key():
    assert fingerprint_program(_gemm_program(m=64)) \
        != fingerprint_program(_gemm_program(m=128))


def test_schedule_changes_key():
    a = _gemm_program()
    b = _gemm_program()
    grp = next(g for g in b.schedule.groups if g.root == "mm")
    grp.impl = "pallas_blockspec"
    grp.config = PallasConfig(128, 128, 128)
    assert fingerprint_program(a) != fingerprint_program(b)


def test_config_field_changes_key():
    a = _gemm_program()
    b = _gemm_program()
    for p in (a, b):
        grp = next(g for g in p.schedule.groups if g.root == "mm")
        grp.impl = "pallas_blockspec"
        grp.config = PallasConfig(128, 128, 128)
    next(g for g in b.schedule.groups if g.root == "mm").config.block_k = 256
    assert fingerprint_program(a) != fingerprint_program(b)


def test_tolerances_and_spec_change_key():
    p = _gemm_program()
    base = fingerprint_program(p, "v5e", "bfloat16", 1e-2, 1e-5, ("gemm",))
    assert base != fingerprint_program(p, "v5e", "bfloat16", 1e-3, 1e-5, ("gemm",))
    assert base != fingerprint_program(p, "v5e", "bfloat16", 1e-2, 1e-4, ("gemm",))
    assert base != fingerprint_program(p, "v4", "bfloat16", 1e-2, 1e-5, ("gemm",))
    assert base != fingerprint_program(p, "v5e", "float32", 1e-2, 1e-5, ("gemm",))
    assert base != fingerprint_program(p, "v5e", "bfloat16", 1e-2, 1e-5, ())
    # tag order is canonicalized
    assert fingerprint_program(p, "v5e", "bfloat16", 1e-2, 1e-5, ("a", "b")) \
        == fingerprint_program(p, "v5e", "bfloat16", 1e-2, 1e-5, ("b", "a"))


def test_op_attr_changes_key():
    a = _gemm_program()
    b = _gemm_program()
    b.graph.node("mm").attrs["transpose_b"] = True
    assert fingerprint_program(a) != fingerprint_program(b)


def test_canonical_map_is_topo_positional():
    p = _gemm_program()
    nm = canonical_name_map(p.graph)
    assert sorted(nm.values()) == sorted(f"n{i}" for i in range(len(nm)))


def test_suite_gemm_family_distinct_keys():
    """Different problems must not collide; rebuilt identical problems must."""
    specs = [s for s in load_specs() if s.family == "gemm"]
    keys = {}
    for s in specs:
        ci = build_program(s.builder, s.dims("ci"), "naive", meta=s.meta)
        bench = build_program(s.builder, s.dims("bench"), "naive", meta=s.meta)
        keys[s.name] = fingerprint_job(ci, bench, "v5e", s.target_dtype,
                                       s.rtol, s.atol, tuple(s.tags))
    assert len(set(keys.values())) == len(keys)
    s = specs[0]
    again = fingerprint_job(
        build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
        build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
        "v5e", s.target_dtype, s.rtol, s.atol, tuple(s.tags))
    assert again == keys[s.name]


def test_program_canonical_roundtrip_stability():
    p = _gemm_program()
    assert program_canonical(p) == program_canonical(p.copy())
