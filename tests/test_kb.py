"""Knowledge base: loading, stage scoping, aliases, extensibility."""

import pathlib
import textwrap

from repro.kb.loader import KnowledgeBase, load_default


def test_load_counts():
    kb = load_default()
    s = kb.stats()
    assert s["constraints"] >= 20
    assert s["patterns"] >= 20
    assert s["examples"] >= 9
    assert s["total_entries"] >= 55


def test_critical_constraints_always_in_prompt():
    kb = load_default()
    txt = kb.format_for_llm("dtype_fix")
    for c in kb.critical_constraints():
        assert c.id in txt


def test_stage_scoping():
    kb = load_default()
    fusion = {p.id for p in kb.patterns_for("fusion")}
    dtype = {p.id for p in kb.patterns_for("dtype_fix")}
    assert "fuse_epilogue_into_matmul" in fusion
    assert "mixed_precision_bf16" in dtype
    assert not fusion & dtype


def test_applicability_filter():
    kb = load_default()
    gemm = kb.patterns_for("gpu_specific", ["gemm"])
    assert any(p.id == "tpu_grid_swizzling" for p in gemm)
    none_match = kb.patterns_for("gpu_specific", ["nonexistent_tag"])
    # patterns without applicability lists still pass; tagged ones filter out
    assert all(not p.applicability or "any" in p.applicability
               for p in none_match)


def test_stage_alias_normalization(tmp_path):
    (tmp_path / "custom.yaml").write_text(textwrap.dedent("""
        patterns:
          - id: custom_pat
            stages: [memory_patterns]          # alias -> memory_access
            rationale: test
            action: {type: set_prefetch}
          - id: unknown_stage_pat
            stages: [not_a_stage]
            rationale: skipped
    """))
    kb = KnowledgeBase.load(tmp_path)
    assert [p.id for p in kb.patterns_for("memory_access")] == ["custom_pat"]
    assert all(p.id != "unknown_stage_pat" for p in kb.patterns)


def test_extensibility_no_code_changes(tmp_path):
    """Drop a new YAML -> discovered on next load (paper §IV-D-e)."""
    (tmp_path / "vendor.yaml").write_text(textwrap.dedent("""
        constraints:
          - id: vendor_rule
            severity: critical
            stages: [gpu_specific]
            description: vendor-specific constraint
        patterns:
          - id: vendor_pattern
            stages: [gpu_specific]
            rationale: vendor idiom
            expected_speedup: 2x
            action: {type: set_config, field: group_m, source: hw_query}
    """))
    kb = KnowledgeBase.load(tmp_path)
    assert any(c.id == "vendor_rule" for c in kb.critical_constraints())
    assert any(p.id == "vendor_pattern" for p in kb.patterns_for("gpu_specific"))
