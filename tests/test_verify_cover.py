"""Verification cascade + CoVeR agent behavior (paper §IV-B)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analyzer import analyze
from repro.core.context import ProblemContext
from repro.core.cover import CoVeRAgent, Trajectory, TrajectoryOverflow
from repro.core.pipeline import ForgePipeline
from repro.core.proposers import Candidate, BaseProposer, make_proposer
from repro.core.verify import SUCCESS, compile_and_verify
from repro.ir import GraphBuilder
from repro.ir.cost import CostModel, graph_flops
from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule
from repro.kb.loader import load_default

KB = load_default()
CM = CostModel()


def _problem(m=256, n=256, k=128, bm=4096, bn=4096, bk=1024):
    def build(M, N, K):
        b = GraphBuilder("p")
        x = b.input((M, K), name="x")
        w = b.param((K, N), name="w")
        mm = b.matmul(x, w, name="mm")
        g = b.done(b.gelu(mm, name="act"))
        sched = eager_schedule(g)
        for grp in sched.groups:
            if grp.root == "mm":
                grp.impl = "pallas_naive"
                grp.config = PallasConfig(128, 128, 32, num_stages=1)
        return KernelProgram("p", g, sched, original_flops=graph_flops(g))
    return build(m, n, k), build(bm, bn, bk)


def _ctx(ci):
    pipe = ForgePipeline()
    return pipe._prepare_ctx("t", ci, ("gemm",), "bfloat16", 1e-2, 1e-3, {})


def test_syntax_level_catches_broken_schedule():
    ci, bench = _problem()
    bad = bench.copy()
    bad.schedule.groups[0].nodes.append("act")  # act now in two groups
    rep = compile_and_verify(ci, bad, 1.0, _ctx(ci), KB)
    assert not rep.ok and rep.level == "syntax"


def test_structure_level_block_alignment():
    ci, bench = _problem()
    for p in (ci, bench):
        g = next(g for g in p.schedule.groups if g.root == "mm")
        g.impl = "pallas_blockspec"
        g.config = PallasConfig(100, 100, 100)  # misaligned
    rep = compile_and_verify(ci, bench, 1.0, _ctx(ci), KB)
    assert not rep.ok and rep.level == "structure"
    assert "INVALID" in rep.observation and "128" in rep.observation


def test_structure_level_vmem_budget():
    ci, bench = _problem()
    g = next(g for g in bench.schedule.groups if g.root == "mm")
    g.impl = "pallas_blockspec"
    g.config = PallasConfig(4096, 4096, 4096, num_stages=3)
    gci = next(g for g in ci.schedule.groups if g.root == "mm")
    gci.impl = "pallas_blockspec"
    gci.config = PallasConfig(128, 128, 128)
    rep = compile_and_verify(ci, bench, 1.0, _ctx(ci), KB)
    assert not rep.ok and rep.level == "structure"
    assert "VMEM" in rep.observation


def test_structure_level_bf16_acc_ban():
    ci, bench = _problem()
    for p in (ci, bench):
        g = next(g for g in p.schedule.groups if g.root == "mm")
        g.impl = "pallas_blockspec"
        g.config = PallasConfig(128, 128, 128, acc_dtype="bfloat16")
    rep = compile_and_verify(ci, bench, 1.0, _ctx(ci), KB)
    assert not rep.ok and rep.level == "structure"
    assert "acc_dtype" in rep.observation


def test_correctness_level_catches_wrong_math():
    ci, bench = _problem()
    # corrupt the candidate: swap gelu for tanh (wrong values, valid program)
    for p in (ci, bench):
        p.graph.node("act").op = "tanh"
    rep = compile_and_verify(ci, bench, 1.0, _ctx(_problem()[0]), KB)
    assert not rep.ok and rep.level == "correctness"
    assert "max_abs_diff" in rep.observation


def test_performance_level_rejects_noops():
    ci, bench = _problem()
    incumbent = CM.program_time(bench)
    rep = compile_and_verify(ci, bench, incumbent, _ctx(ci), KB)
    assert not rep.ok and rep.level == "performance"
    assert "SLOWER" in rep.observation or "Suggestions" in rep.observation


def test_success_sentinel():
    ci, bench = _problem()
    incumbent = CM.program_time(bench)
    for p in (ci, bench):
        g = next(g for g in p.schedule.groups if g.root == "mm")
        g.impl = "pallas_blockspec"
        g.config = PallasConfig(512, 512, 512, num_stages=2)
    rep = compile_and_verify(ci, bench, incumbent, _ctx(_problem()[0]), KB)
    assert rep.ok and rep.level == "success"
    assert rep.speedup > 1


def test_trajectory_truncation():
    t = Trajectory(max_chars=400)
    for i in range(10):
        t.add(f"thought {i}", "tool", "args", "obs " + "x" * 80)
    assert len(t.entries) < 10  # oldest dropped
    with pytest.raises(TrajectoryOverflow):
        t2 = Trajectory(max_chars=10)
        t2.add("a" * 50, "t", "a", "o")


class FailingThenGoodProposer(BaseProposer):
    """First candidate violates VMEM; second reacts to the error (refine)."""
    stage = "gpu_specific"

    def candidates(self, program, issues, trajectory):
        last = trajectory[-1]["observation"] if trajectory else ""
        if "VMEM" in last:
            def fix(p):
                p = p.copy()
                for g in p.schedule.groups:
                    if g.impl.startswith("pallas"):
                        g.impl = "pallas_blockspec"
                        g.config = PallasConfig(512, 512, 512)
                return p
            yield Candidate("shrink after VMEM feedback", "fix", fix, "p2")
        else:
            def bad(p):
                p = p.copy()
                for g in p.schedule.groups:
                    if g.impl.startswith("pallas"):
                        g.impl = "pallas_blockspec"
                        g.config = PallasConfig(8192, 8192, 8192, num_stages=3)
                return p
            yield Candidate("huge blocks", "bad", bad, "p1")


def test_cover_refines_on_feedback():
    ci, bench = _problem()
    ctx = _ctx(ci)
    agent = CoVeRAgent("gpu_specific", FailingThenGoodProposer(KB, ctx), KB,
                       max_iterations=5)
    res = agent.run(ci, bench, [], ctx, CM.program_time(bench), CM)
    assert res.improved
    assert res.iterations == 2  # failed once, refined, succeeded
    assert "VMEM" in res.trajectory.entries[0]["observation"]


class HopelessProposer(BaseProposer):
    stage = "gpu_specific"

    def candidates(self, program, issues, trajectory):
        def noop(p):
            return p.copy()
        yield Candidate("does nothing", "noop", noop, "p0")


def test_cover_never_degrades():
    ci, bench = _problem()
    ctx = _ctx(ci)
    agent = CoVeRAgent("gpu_specific", HopelessProposer(KB, ctx), KB,
                       max_iterations=3)
    incumbent = CM.program_time(bench)
    res = agent.run(ci, bench, [], ctx, incumbent, CM)
    assert not res.improved
    assert CM.program_time(res.bench_program) == pytest.approx(incumbent)
