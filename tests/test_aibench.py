"""AI Bench: spec loading, safe formula eval, timing, CSV logging, compare."""

import os
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.aibench import (CSVLogger, build_program, compare_programs,
                           load_specs, safe_eval, time_fn)
from repro.aibench.spec import ProblemSpec, Variant


def test_specs_load_and_cover_families():
    specs = load_specs()
    assert len(specs) >= 28
    fams = {s.family for s in specs}
    assert fams >= {"gemm", "matmul", "bmm", "conv2d", "conv3d", "convt2d",
                    "convt3d"}
    for s in specs:
        assert "ci" in s.variants and "bench" in s.variants
        assert s.builder  # registered
        build_program(s.builder, s.dims("ci"))  # must construct


def test_flop_formula_eval():
    spec = next(s for s in load_specs() if s.name == "gemm_bias_gelu")
    d = spec.dims("bench")
    want = 2 * d["M"] * d["N"] * d["K"] + 10 * d["M"] * d["N"]
    assert spec.flops("bench") == pytest.approx(want)


def test_safe_eval_rejects_evil():
    assert safe_eval("2*M*N", {"M": 3, "N": 4}) == 24
    assert safe_eval("M**2 - N/2", {"M": 3, "N": 4}) == 7
    for evil in ("__import__('os')", "M.__class__", "(lambda: 1)()",
                 "[x for x in (1,)]", "M if N else 0"):
        with pytest.raises(Exception):
            safe_eval(evil, {"M": 1, "N": 1})
    with pytest.raises(KeyError):
        safe_eval("M*Q", {"M": 1})


def test_time_fn_trims_and_reports():
    calls = []

    def fn():
        calls.append(1)
        return jnp.ones(4)

    stats = time_fn(fn, warmup=2, iters=6)
    assert stats["iters"] == 6
    assert len(calls) == 8
    assert stats["min_us"] <= stats["mean_us"] <= stats["max_us"]


def test_csv_logger_env_capture(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_CARD", "v5e-sim")
    log = CSVLogger(tmp_path / "r.csv")
    log.log(kernel="k1", backend="triton", flops=1e9, tflops=1.0,
            time_us=1000.0, dims={"M": 8})
    text = (tmp_path / "r.csv").read_text()
    assert "repro_bench_card" in text.splitlines()[0]
    assert "v5e-sim" in text
    assert "k1" in text


def test_compare_programs_pass_and_diagnose():
    spec = next(s for s in load_specs() if s.name == "gemm_bias_gelu")
    ref = build_program(spec.builder, spec.dims("ci"), "eager")
    same = build_program(spec.builder, spec.dims("ci"), "naive")
    res = compare_programs(ref, same, rtol=1e-2, atol=1e-3)
    assert res.correct, res.feedback

    wrong = build_program(spec.builder, spec.dims("ci"), "naive")
    wrong.graph.node("act").op = "tanh"
    res = compare_programs(ref, wrong, rtol=1e-2, atol=1e-3)
    assert not res.correct
    assert res.exceed_count > 0 and "max_abs" in res.feedback
