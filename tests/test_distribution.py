"""Distribution substrate tests on fake devices (subprocess-isolated where a
different device count is needed; jax locks the count at first init)."""

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import param_spec

    class FakeMesh:
        shape = {"data": 4, "model": 4}
    m = FakeMesh()
    # column-parallel + fsdp on the free dim
    assert param_spec(("layers", "attn", "wq"), (8, 512, 512), m) == \
        P("data", None, "model")
    # row-parallel
    assert param_spec(("layers", "mlp", "wo"), (8, 512, 256), m) == \
        P("data", "model", None)
    # divisibility fallback: odd vocab shards d_model instead
    assert param_spec(("embed",), (51865, 768), m) == P(None, "model")
    assert param_spec(("embed",), (64000, 768), m) == P("model", "data")
    # norms replicated
    assert param_spec(("ln1", "scale"), (512,), m) == P()
    # moe experts: F over model, fsdp on first dividing dim
    spec = param_spec(("layers", "moe", "wi"), (8, 8, 512, 1024), m)
    assert spec == P("data", None, None, "model")


def test_cache_spec_batch1_unsharded_dp():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import cache_spec

    class FakeMesh:
        shape = {"data": 4, "model": 4}
    m = FakeMesh()
    spec = cache_spec(("k",), (26, 1, 2048, 1, 256), m, kv_heads=1)
    assert spec[1] is None  # batch=1 cannot shard over dp
    spec = cache_spec(("k",), (28, 8, 4096, 4, 128), m, kv_heads=4)
    assert spec == P(None, ("data",), None, "model", None)


def test_grad_compression_int8_ef():
    """Cross-pod int8 EF reduction ~= f32 mean; error feedback shrinks bias
    across steps."""
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.train.grad_compress import cross_pod_mean, compression_ratio
        mesh = make_test_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
        err = jax.tree.map(jnp.zeros_like, g)
        with mesh:
            red, err2 = cross_pod_mean(g, err, mesh)
        # replicated input -> mean == input (within int8 quantization)
        q = np.abs(np.asarray(red["w"]) - np.asarray(g["w"])).max()
        scale = np.abs(np.asarray(g["w"])).max() / 127
        assert q <= scale * 1.01, (q, scale)
        # error feedback captured the quantization residual
        assert np.abs(np.asarray(err2["w"])).max() <= scale * 0.51
        assert compression_ratio(g) > 3.9
        print("OK")
    """), devices=4)
    assert "OK" in out


def test_expert_parallel_matches_dense():
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.models.layers import apply_moe, init_moe
        from repro.sharding.expert_parallel import apply_moe_ep
        cfg = get_config("grok-1-314b").reduced()  # 4 experts
        mesh = make_test_mesh((4,), ("expert",))
        p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        dense = apply_moe(cfg, p, x, capacity_factor=8.0)
        with mesh:
            ep = apply_moe_ep(cfg, p, x, mesh, axis="expert",
                              capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(ep), np.asarray(dense),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """), devices=4)
    assert "OK" in out


def test_pipeline_parallel_matches_reference():
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.train.pipeline_parallel import (init_mlp_pipeline,
            mlp_stage_fn, pipeline_forward, reference_forward)
        mesh = make_test_mesh((4,), ("pipe",))
        params = init_mlp_pipeline(jax.random.PRNGKey(0), 4, 16, 32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))  # 8 microbatches
        fn = pipeline_forward(mesh, mlp_stage_fn, 4, 8)
        with mesh:
            got = fn(params, x)
        want = reference_forward(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """), devices=4)
    assert "OK" in out


def test_sharded_train_step_runs_and_matches_single():
    """A small sharded train step on a (2,2) mesh matches the 1-device run."""
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.models.model import RuntimeFlags, init_params
        from repro.optim import adamw
        from repro.sharding import rules
        from repro.train.train_step import TrainConfig, make_train_step
        cfg = get_config("olmo-1b").reduced()
        flags = RuntimeFlags(remat=False, chunked_attention=False)
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = adamw.init(adamw.AdamWConfig(), params)
        tk = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
        batch = {"tokens": tk, "labels": tk}
        step = make_train_step(cfg, flags, TrainConfig())
        p0, o0, m0 = jax.jit(step)(params, opt, batch)   # single device

        mesh = make_test_mesh((2, 2), ("data", "model"))
        shp = rules.shard_params(params, mesh)
        params_s = jax.device_put(params, shp)
        opt_s = adamw.OptState(
            m=jax.device_put(opt.m, rules.shard_params(opt.m, mesh)),
            v=jax.device_put(opt.v, rules.shard_params(opt.v, mesh)),
            step=opt.step)
        batch_s = jax.device_put(batch, rules.shard_batch(
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()},
            mesh))
        with mesh:
            p1, o1, m1 = jax.jit(step)(params_s, opt_s, batch_s)
        np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                                   rtol=1e-4)
        l0 = jax.tree.leaves(p0)
        l1 = jax.tree.leaves(p1)
        for a, b in zip(l0, l1):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)
        print("OK")
    """), devices=4)
    assert "OK" in out


def test_elastic_checkpoint_across_device_counts(tmp_path):
    """Save sharded on 4 devices, restore+train on 2 (elastic restart)."""
    code = textwrap.dedent(f"""
        import jax, jax.numpy as jnp
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.configs.base import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.models.model import init_params
        from repro.sharding import rules
        cfg = get_config("olmo-1b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        mesh = make_test_mesh((DEV, 1), ("data", "model"))
        params = jax.device_put(params, rules.shard_params(params, mesh))
        mgr = CheckpointManager(r"{tmp_path}")
        STEP
    """)
    save = code.replace("DEV", "4").replace(
        "STEP", "mgr.save(1, params); print('SAVED')")
    out = _run(save, devices=4)
    assert "SAVED" in out
    load = code.replace("DEV", "2").replace("STEP", textwrap.dedent("""
        restored, step = mgr.restore(
            params, shardings=rules.shard_params(params, mesh))
        import numpy as np
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('RESTORED', step)
    """))
    out = _run(load, devices=2)
    assert "RESTORED 1" in out
