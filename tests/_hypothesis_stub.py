"""Minimal deterministic stand-in for ``hypothesis`` when it isn't installed.

The container image doesn't ship hypothesis and we can't add dependencies, so
``tests/conftest.py`` registers this module under ``sys.modules['hypothesis']``
before test collection. It covers exactly the API surface the suite uses:
``given`` (keyword strategies only), ``settings(max_examples, deadline)``, and
``strategies.integers / sampled_from / booleans / floats``. Examples are drawn
from a fixed-seed RNG so runs are reproducible.
"""

from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value=0, max_value=2**31 - 1):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: elements[r.randrange(len(elements))])


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def just(value):
    return _Strategy(lambda r: value)


def given(**strategy_kw):
    if not strategy_kw:
        raise TypeError("stub @given supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 20)
            rng = random.Random(0)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strategy_kw.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except _StubAssumption:
                    continue

        # hide strategy-bound params from pytest so they aren't treated as
        # fixtures (real hypothesis rewrites the signature the same way)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategy_kw])
        return wrapper

    return deco


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def assume(condition):
    if not condition:
        raise _StubAssumption()


class _StubAssumption(Exception):
    pass
