import os
import sys
import pathlib

# tests must see exactly ONE device (dry-runs get 512 in their own procs)
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

# the image doesn't ship hypothesis; fall back to the deterministic stub so
# the property tests still exercise a sampled subset of their domains
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub
    _hypothesis_stub.strategies = _hypothesis_stub

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (dry-run subprocess)")
