"""Job codec: bit-exact wire round-trips for every suite builder, JSON and
pickle-across-spawn transport, and the PipelineResult up-channel."""

import json
import multiprocessing
import pickle

import pytest

from repro.aibench import build_program, load_specs
from repro.aibench.suite import BUILDERS
from repro.core import KernelJob
from repro.core.job_codec import (decode_job, decode_pipeline_result,
                                  decode_program, encode_job,
                                  encode_pipeline_result, encode_program,
                                  job_fingerprint_from_wire)
from repro.ir.fingerprint import program_canonical

SPECS = load_specs()


def _job(spec):
    return KernelJob(spec.name,
                     build_program(spec.builder, spec.dims("ci"), "naive",
                                   meta=spec.meta),
                     build_program(spec.builder, spec.dims("bench"), "naive",
                                   meta=spec.meta),
                     tags=tuple(spec.tags), target_dtype=spec.target_dtype,
                     rtol=spec.rtol, atol=spec.atol, meta=dict(spec.meta))


def test_specs_cover_every_builder():
    """The parametrized round-trip below runs one spec per builder; this
    guard keeps that claim honest when new builders are registered."""
    assert set(BUILDERS) == {s.builder for s in SPECS}


@pytest.mark.parametrize("spec", SPECS, ids=[s.name for s in SPECS])
def test_roundtrip_bit_identical_fingerprint(spec):
    """codec(decode(x)) preserves the exact structural fingerprint — the
    property that lets a worker process compute the same cache keys the
    parent did — for every registered kernel builder."""
    job = _job(spec)
    # force a real JSON transit, not just dict identity
    wire = json.loads(json.dumps(encode_job(job)))
    back = decode_job(wire)
    assert back.fingerprint("tpu_v5e") == job.fingerprint("tpu_v5e")
    assert back.family_fingerprint("tpu_v5e") \
        == job.family_fingerprint("tpu_v5e")
    assert program_canonical(back.ci_program) \
        == program_canonical(job.ci_program)
    assert program_canonical(back.bench_program) \
        == program_canonical(job.bench_program)
    assert back.tags == job.tags and back.meta == job.meta
    assert back.rtol == job.rtol and back.atol == job.atol


def test_tuple_attrs_survive_json():
    """Node attrs written as tuples (perm, axes, dimension_semantics) must
    come back as tuples, not lists — the interpreter reads them directly."""
    spec = next(s for s in SPECS if s.builder == "gemm_transpose_transpose")
    job = _job(spec)
    wire = json.loads(json.dumps(encode_job(job)))
    back = decode_job(wire)
    orig_nodes = job.ci_program.graph.nodes
    for name, node in back.ci_program.graph.nodes.items():
        assert node.attrs == orig_nodes[name].attrs
        assert all(type(v) is type(orig_nodes[name].attrs[k])
                   for k, v in node.attrs.items())
        assert node.shape == orig_nodes[name].shape
        assert isinstance(node.shape, tuple)


def test_program_roundtrip_executes():
    """A decoded program is a live KernelProgram: it validates and can be
    mutated (fresh node names don't collide with decoded ones)."""
    spec = SPECS[0]
    prog = build_program(spec.builder, spec.dims("ci"), "naive",
                         meta=spec.meta)
    back = decode_program(json.loads(json.dumps(encode_program(prog))))
    back.validate()
    copy = back.copy()
    new = copy.graph.add("relu", [copy.graph.outputs[0]])
    assert new not in prog.graph.nodes


def test_pipeline_result_roundtrip():
    """The worker->parent up-channel: a full PipelineResult survives the
    wire with programs, records, issues and log intact."""
    from repro.forge import Forge, ForgeConfig

    spec = next(s for s in SPECS if s.name == "gemm_bias_gelu")
    forge = Forge(ForgeConfig(execution_backend="serial"))
    res = forge.optimize(_job(spec)).result.result
    wire = json.loads(json.dumps(encode_pipeline_result(res)))
    back = decode_pipeline_result(wire)
    assert back.name == res.name
    assert back.optimized_time == res.optimized_time
    assert back.original_time == res.original_time
    assert program_canonical(back.bench_program) \
        == program_canonical(res.bench_program)
    assert back.transform_log.to_list() == res.transform_log.to_list()
    assert [r.stage for r in back.stage_records] \
        == [r.stage for r in res.stage_records]
    assert [i.type for i in back.issues_initial] \
        == [i.type for i in res.issues_initial]
    assert back.clamped == res.clamped
    assert back.seed_steps_applied == res.seed_steps_applied


def test_wire_is_picklable():
    job = _job(SPECS[0])
    wire = encode_job(job)
    assert pickle.loads(pickle.dumps(wire)) == wire


def test_fingerprint_across_spawn():
    """The pickle-across-spawn property the process backend rests on: a
    freshly spawned interpreter decoding the wire form computes the exact
    same fingerprint as this process."""
    spec = next(s for s in SPECS if s.name == "gemm_bias_gelu")
    job = _job(spec)
    wire = json.loads(json.dumps(encode_job(job)))
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(1) as pool:
        remote = pool.apply(job_fingerprint_from_wire, (wire, "tpu_v5e", ""))
    assert remote == job.fingerprint("tpu_v5e")
