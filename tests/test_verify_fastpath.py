"""Verification fast path (``ForgeConfig.verify_fastpath``): check-mode
equivalence over the rewrite corpus, fingerprint-driven invalidation (group
mutation + KB content-hash change), cost-first screening, trajectory budget
accounting, and worker-side key computation."""

import dataclasses

import pytest

from repro.core.config import ForgeConfig
from repro.core.cover import CoVeRAgent, Trajectory
from repro.core.engine import OptimizationEngine, compute_job_keys
from repro.core.pipeline import ForgePipeline
from repro.core.proposers import BaseProposer, Candidate
from repro.core.result_store import ResultStore
from repro.core.verify import compile_and_verify, verify_candidate
from repro.core.verify_cache import VerifySession, run_program_cached
from repro.ir import GraphBuilder
from repro.ir.cost import CostModel, graph_flops
from repro.ir.fingerprint import program_canonical
from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule
from repro.kb.loader import KnowledgeBase, load_default

KB = load_default()
CM = CostModel()


def _gemm(name, m, n, k, dtype="float32"):
    b = GraphBuilder(name, dtype=dtype)
    x = b.input((m, k), name="x")
    w = b.param((k, n), name="w")
    mm = b.matmul(x, w, name="mm")
    g = b.done(b.gelu(mm, name="act"))
    sched = eager_schedule(g)
    for grp in sched.groups:
        if grp.root == "mm":
            grp.impl = "pallas_naive"
            grp.config = PallasConfig(128, 128, 32, num_stages=1)
    return KernelProgram(name, g, sched, original_flops=graph_flops(g))


def _problem(m=256, n=256, k=128, bm=4096, bn=4096, bk=1024):
    return _gemm("p", m, n, k), _gemm("p", bm, bn, bk)


def _ctx(pipe, ci, session=None):
    return pipe._prepare_ctx("t", ci, ("gemm",), "bfloat16", 1e-2, 1e-3, {},
                             session=session)


def _result_view(r):
    return {
        "log": r.transform_log.to_list(),
        "records": [dataclasses.asdict(s) for s in r.stage_records],
        "original_time": r.original_time,
        "optimized_time": r.optimized_time,
        "proposals": r.proposals,
        "clamped": r.clamped,
        "schedule": program_canonical(r.bench_program)["schedule"],
    }


# ----------------------------------------------------------------------
# check mode: the fast path's executable contract
# ----------------------------------------------------------------------

def test_check_mode_holds_over_pipeline_corpus():
    """Acceptance criterion: verify_fastpath='check' cross-checks every
    report of a full optimization against the uncached cascade and reports
    zero divergences (it would raise VerifyFastpathDivergence)."""
    pipe = ForgePipeline(config=ForgeConfig(verify_fastpath="check"))
    r = pipe.optimize("chk", _gemm("chk", 256, 256, 128),
                      _gemm("chk", 2048, 2048, 512), tags=("gemm",))
    assert r.transform_log is not None and len(r.transform_log) > 0


def test_on_off_pipeline_equivalence():
    """The fast path (memoization + cost-first screening) must be
    result-equivalent end to end: identical transform logs, stage records,
    modeled times and proposal counts."""
    views = {}
    for mode in ("off", "on"):
        pipe = ForgePipeline(config=ForgeConfig(verify_fastpath=mode))
        r = pipe.optimize("eq", _gemm("eq", 256, 256, 128),
                          _gemm("eq", 4096, 4096, 1024), tags=("gemm",))
        views[mode] = _result_view(r)
    assert views["on"] == views["off"]


def test_check_mode_single_reports_match_reference():
    """Point check: a fresh session's verify_candidate('check') returns the
    same report object content as the plain cascade, hot and cold."""
    ci, bench = _problem()
    pipe = ForgePipeline()
    session = VerifySession()
    ctx = _ctx(pipe, ci)
    ref = compile_and_verify(ci, bench, 1.0, ctx, KB, CM)
    for _ in range(2):   # cold then memo-hot
        got = verify_candidate(ci, bench, 1.0, ctx, KB, CM,
                               session=session, fastpath="check")
        assert got == ref


# ----------------------------------------------------------------------
# fingerprint-driven invalidation
# ----------------------------------------------------------------------

def test_group_cache_invalidates_downstream_slice_only():
    ci, _ = _problem()
    pipe = ForgePipeline()
    session = VerifySession()
    ctx = _ctx(pipe, ci)
    n_groups = len(ci.schedule.groups)
    assert n_groups == 2                       # g_mm, g_act

    run_program_cached(ci, ctx.ci_inputs, ctx.ci_params, session)
    assert session.stats.group_misses == n_groups
    assert session.stats.group_hits == 0

    # identical structure (fresh copy): full replay, zero executions
    run_program_cached(ci.copy(), ctx.ci_inputs, ctx.ci_params, session)
    assert session.stats.group_hits == n_groups

    # mutate the LAST group (act): upstream mm replays, act re-executes
    tail = ci.copy()
    tail.graph.node("act").op = "tanh"
    run_program_cached(tail, ctx.ci_inputs, ctx.ci_params, session)
    assert session.stats.group_hits == n_groups + 1          # mm hit
    assert session.stats.group_misses == n_groups + 1        # act missed

    # mutate the FIRST group (mm tiles, different effective blocks): the
    # whole downstream slice re-executes
    head = ci.copy()
    for grp in head.schedule.groups:
        if grp.root == "mm":
            grp.config = PallasConfig(64, 64, 32, num_stages=1)
    run_program_cached(head, ctx.ci_inputs, ctx.ci_params, session)
    assert session.stats.group_misses == n_groups + 3        # mm + act missed


def test_group_cache_reuses_renamed_structural_twin():
    """Cached group outputs are stored positionally: a mutating rewrite that
    only relabels the tail node replays the upstream slice."""
    ci, _ = _problem()
    pipe = ForgePipeline()
    session = VerifySession()
    ctx = _ctx(pipe, ci)
    run_program_cached(ci, ctx.ci_inputs, ctx.ci_params, session)
    misses = session.stats.group_misses

    twin = ci.copy()
    g = twin.graph
    node = g.nodes.pop("act")
    node.name = "act_renamed"
    g.nodes["act_renamed"] = node
    g.outputs = ["act_renamed"]
    for grp in twin.schedule.groups:
        grp.nodes = [n if n != "act" else "act_renamed" for n in grp.nodes]
        if grp.root == "act":
            grp.root = "act_renamed"
            grp.name = "g_act_renamed"
    out = run_program_cached(twin, ctx.ci_inputs, ctx.ci_params, session)
    assert session.stats.group_misses == misses          # full replay
    assert "act_renamed" in out


def test_effective_config_collapses_identical_dispatch():
    """Two configs that clamp to the same effective template blocks on ci
    shapes share one cached execution (the group_exec_signature contract)."""
    ci, _ = _problem(m=256, n=256, k=128)
    pipe = ForgePipeline()
    session = VerifySession()
    ctx = _ctx(pipe, ci)
    big = ci.copy()
    for grp in big.schedule.groups:
        if grp.root == "mm":
            grp.config = PallasConfig(512, 512, 512, num_stages=1)
    bigger = ci.copy()
    for grp in bigger.schedule.groups:
        if grp.root == "mm":
            grp.config = PallasConfig(1024, 1024, 1024, num_stages=1)
    run_program_cached(big, ctx.ci_inputs, ctx.ci_params, session)
    misses = session.stats.group_misses
    run_program_cached(bigger, ctx.ci_inputs, ctx.ci_params, session)
    assert session.stats.group_misses == misses          # both clamp to 256


def test_structure_memo_invalidates_on_kb_content_change():
    """Acceptance criterion: the fast path's memoized structure verdicts key
    on KnowledgeBase.content_hash(), so a KB swap/edit is reflected
    immediately even within one session."""
    ci, bench = _problem()
    f64 = _gemm("p", 256, 256, 128, dtype="float64")
    f64b = _gemm("p", 4096, 4096, 1024, dtype="float64")
    pipe = ForgePipeline()
    session = VerifySession()
    ctx = _ctx(pipe, f64)

    kb_empty = KnowledgeBase([], [], [])
    assert kb_empty.content_hash() != KB.content_hash()

    with_kb = compile_and_verify(f64, f64b, 1.0, ctx, KB, CM,
                                 session=session)
    assert with_kb.level == "structure" and "float64" in with_kb.observation
    # memo hot for the same KB
    again = compile_and_verify(f64, f64b, 1.0, ctx, KB, CM, session=session)
    assert again == with_kb and session.stats.structure_hits >= 1

    # same session, different KB content hash -> the dtype ban is gone
    without = compile_and_verify(f64, f64b, 1.0, ctx, kb_empty, CM,
                                 session=session)
    assert without.level != "structure" or "float64" not in without.observation
    # and the original KB's memo entry is still intact
    assert compile_and_verify(f64, f64b, 1.0, ctx, KB, CM,
                              session=session) == with_kb


# ----------------------------------------------------------------------
# cost-first screening
# ----------------------------------------------------------------------

class NoopProposer(BaseProposer):
    stage = "gpu_specific"

    def candidates(self, program, issues, trajectory):
        yield Candidate("does nothing", "noop", lambda p: p.copy(), "p0")


def test_screening_defers_correctness_and_matches_unscreened():
    ci, bench = _problem()
    pipe = ForgePipeline()
    incumbent = CM.program_time(bench)
    results = {}
    for mode in ("off", "on"):
        session = VerifySession() if mode != "off" else None
        ctx = _ctx(pipe, ci)
        agent = CoVeRAgent("gpu_specific", NoopProposer(KB, ctx), KB,
                           max_iterations=3, session=session, fastpath=mode)
        res = agent.run(ci, bench, [], ctx, incumbent, CM)
        results[mode] = res
        if mode == "on":
            # the noop can't beat the incumbent -> correctness was deferred,
            # then lazily executed once by the fallback extractor
            assert session.stats.screened >= 1
            assert session.stats.deferred_runs == 1
    off, on = results["off"], results["on"]
    assert (off.improved, off.iterations, off.fallback_used) \
        == (on.improved, on.iterations, on.fallback_used)
    assert CM.program_time(off.bench_program) \
        == pytest.approx(CM.program_time(on.bench_program))


def test_check_mode_validates_screening_for_incorrect_slow_candidate():
    """check mode also cross-checks the screening decision: a candidate that
    is both slower and incorrect (the one class where screening changes the
    failure level) must validate cleanly — its lazily-run correctness
    agrees with the reference."""
    ci, bench = _problem()
    for p in (ci, bench):
        p.graph.node("act").op = "tanh"        # wrong math, valid program
    good_ci, _ = _problem()
    pipe = ForgePipeline()
    ctx = _ctx(pipe, good_ci)
    incumbent = CM.program_time(bench) / 100   # candidate is also "slower"
    session = VerifySession()
    got = verify_candidate(ci, bench, incumbent, ctx, KB, CM,
                           session=session, fastpath="check")
    assert got.level == "correctness"          # reference outcome returned
    assert session.stats.screened >= 1         # the screen actually fired


def test_screened_report_matches_unscreened_for_correct_candidate():
    """For a correct-but-slow candidate the screened report must be
    byte-identical to the unscreened performance failure (modulo the
    deferred flag)."""
    ci, bench = _problem()
    pipe = ForgePipeline()
    ctx = _ctx(pipe, ci)
    incumbent = CM.program_time(bench)
    ref = compile_and_verify(ci, bench, incumbent, ctx, KB, CM)
    assert ref.level == "performance"
    screened = compile_and_verify(ci, bench, incumbent, ctx, KB, CM,
                                  session=VerifySession(), cost_first=True)
    assert screened.correctness_deferred
    assert dataclasses.replace(screened, correctness_deferred=False) == ref


# ----------------------------------------------------------------------
# trajectory budget accounting (satellite: O(n^2) add fix)
# ----------------------------------------------------------------------

def test_trajectory_running_length_matches_format():
    t = Trajectory(max_chars=2000)
    for i in range(40):   # indices reach two digits; truncation kicks in
        t.add(f"thought {i}", "compile_and_verify", f"args-{i}",
              "observation " + "x" * (17 * (i % 7)))
        assert t._formatted_len() == len(t.format())
        assert len(t.format()) <= t.max_chars
    assert len(t.entries) < 40


def test_trajectory_truncation_behavior_unchanged():
    t = Trajectory(max_chars=400)
    for i in range(10):
        t.add(f"thought {i}", "tool", "args", "obs " + "x" * 80)
    assert len(t.entries) < 10
    assert t._formatted_len() == len(t.format())


# ----------------------------------------------------------------------
# parallel dispatch: worker-side keys and the sharded store
# ----------------------------------------------------------------------

def _job(m, n, k, name="gemm"):
    from repro.core import KernelJob
    return KernelJob(name, _gemm(name, min(m, 256), min(n, 256), min(k, 128)),
                     _gemm(name, m, n, k), tags=("gemm",))


def test_thread_backend_computes_identical_keys():
    jobs = [_job(2048, 2048, 512, name=f"j{i}") for i in range(3)]
    serial = OptimizationEngine(workers=1, backend="serial")
    threaded = OptimizationEngine(workers=3, backend="thread")
    ref = serial._get_executor().compute_keys(jobs)
    assert threaded._get_executor().compute_keys(jobs) == ref
    assert ref == [compute_job_keys(serial.pipeline, j) for j in jobs]


def test_sharded_store_concurrent_access():
    import threading

    store = ResultStore(max_entries=256, shards=4)
    errors = []

    def hammer(tid):
        try:
            for i in range(50):
                key = f"k{tid}-{i % 10}"
                store.put(key, {"transform_log": [], "x": i},
                          family=f"fam{tid}", flush=False)
                assert store.get(key) is not None
                store.family_members(f"fam{tid}")
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors
    assert len(store) == 8 * 10
    for t in range(8):
        assert len(store.family_members(f"fam{t}")) == 10


def test_store_heap_eviction_exact_under_churn():
    """The lazy recency heap must keep eviction exactly LRU through heavy
    stamp churn (refreshes create stale stamps that eviction must skip)."""
    store = ResultStore(max_entries=4, shards=3)
    for i in range(4):
        store.put(f"k{i}", {"transform_log": []}, flush=False)
    for _ in range(30):                       # pile up stale stamps
        store.get("k0"), store.get("k1")
    store.put("k4", {"transform_log": []}, flush=False)   # evicts k2 (LRU)
    assert store.get("k2") is None
    store.put("k5", {"transform_log": []}, flush=False)   # evicts k3
    assert store.get("k3") is None
    for key in ("k0", "k1", "k4", "k5"):
        assert store.get(key) is not None
    assert len(store) == 4 and store.evictions == 2


def test_sharded_store_single_thread_semantics_match_unsharded():
    """Global LRU must stay exact across shards: the shard count can never
    change eviction order or disk layout."""
    a = ResultStore(max_entries=3, shards=1)
    b = ResultStore(max_entries=3, shards=7)
    for store in (a, b):
        for i in range(5):
            store.put(f"k{i}", {"transform_log": [], "i": i}, flush=False)
        store.get("k2")                       # refresh
        store.put("k5", {"transform_log": []}, flush=False)
    for key in ("k0", "k1", "k3"):
        assert a.get(key) is None and b.get(key) is None
    for key in ("k2", "k4", "k5"):
        assert a.get(key) is not None and b.get(key) is not None
    assert a.evictions == b.evictions
