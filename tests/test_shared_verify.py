"""Cross-job oracle sharing: content-addressed fingerprints, the engine's
SharedVerifyCache (byte-LRU exactness, read-through/write-back sessions,
positional oracle rebinding), the batch execution planner, backend
equivalence with planning on, and check-mode detection of poisoned shared
entries."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import KernelJob
from repro.core.pipeline import prepare_oracle
from repro.core.verify_cache import (SharedVerifyCache,
                                     VerifyFastpathDivergence, VerifySession,
                                     run_program_cached)
from repro.forge import Forge, ForgeConfig
from repro.ir import GraphBuilder
from repro.ir.cost import graph_flops
from repro.ir.fingerprint import (array_content_fingerprint,
                                  content_leaf_fingerprint,
                                  graph_oracle_fingerprint,
                                  program_exec_fingerprint)
from repro.ir.interpreter import make_inputs, make_params
from repro.ir.schedule import (KernelProgram, PallasConfig, eager_schedule,
                               rename_program)


def _gemm(name, m, n, k, dtype="float32"):
    b = GraphBuilder(name, dtype=dtype)
    x = b.input((m, k), name="x")
    w = b.param((k, n), name="w")
    mm = b.matmul(x, w, name="mm")
    g = b.done(b.gelu(mm, name="act"))
    sched = eager_schedule(g)
    for grp in sched.groups:
        if grp.root == "mm":
            grp.impl = "pallas_naive"
            grp.config = PallasConfig(128, 128, 32, num_stages=1)
    return KernelProgram(name, g, sched, original_flops=graph_flops(g))


def _arr(fill, n=25):
    return np.full(n, fill, dtype=np.float32)  # 100 bytes each


# ----------------------------------------------------------------------
# content-addressed fingerprints
# ----------------------------------------------------------------------

def test_array_content_fingerprint_tracks_values():
    a = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    b = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)   # distinct object
    assert a is not b
    assert array_content_fingerprint(a) == array_content_fingerprint(b)
    # memo is id-keyed: the same object returns the same digest
    assert array_content_fingerprint(a) == array_content_fingerprint(a)

    flipped = np.asarray(a).copy()
    flipped.flat[0] = np.nextafter(flipped.flat[0], np.float32(np.inf))
    assert (array_content_fingerprint(jnp.asarray(flipped))
            != array_content_fingerprint(a))
    # shape and dtype participate even when the bytes agree
    assert (array_content_fingerprint(a.reshape(4, 3))
            != array_content_fingerprint(a))
    assert (array_content_fingerprint(jnp.zeros(4, jnp.float32))
            != array_content_fingerprint(jnp.zeros(8, jnp.float16)))


def test_content_leaf_fingerprint_is_name_free():
    """Two leaves with different names bound to bit-identical arrays share
    one fingerprint — the property cross-job group sharing rests on."""
    p = _gemm("p", 64, 64, 32)
    t = rename_program(p, "z_")
    val = make_inputs(p.graph)["x"]
    a = content_leaf_fingerprint(p.graph.node("x"), val)
    b = content_leaf_fingerprint(t.graph.node("z_x"), val)
    assert a == b
    bumped = np.asarray(val).copy()
    bumped.flat[0] += 1
    assert content_leaf_fingerprint(p.graph.node("x"),
                                    jnp.asarray(bumped)) != a


# ----------------------------------------------------------------------
# cross-session sharing through SharedVerifyCache
# ----------------------------------------------------------------------

def test_renamed_twin_shares_group_executions_across_sessions():
    p = _gemm("p", 64, 64, 32)
    t = rename_program(p, "z_")
    n_groups = len(p.schedule.groups)
    shared = SharedVerifyCache(64 * 1024 * 1024)

    sa = VerifySession(shared=shared)
    out_a = run_program_cached(p, make_inputs(p.graph), make_params(p.graph),
                               sa)
    assert sa.stats.group_misses == n_groups
    assert sa.stats.shared_group_hits == 0

    # the twin's seeded arrays are bit-identical (seeding is positional,
    # names never feed the PRNG), so every group key matches
    sb = VerifySession(shared=shared)
    out_b = run_program_cached(t, make_inputs(t.graph), make_params(t.graph),
                               sb)
    assert sb.stats.shared_group_hits == n_groups
    np.testing.assert_array_equal(np.asarray(out_a["act"]),
                                  np.asarray(out_b["z_act"]))


def test_one_bit_input_difference_defeats_sharing():
    p = _gemm("p", 64, 64, 32)
    shared = SharedVerifyCache(64 * 1024 * 1024)
    inputs, params = make_inputs(p.graph), make_params(p.graph)
    run_program_cached(p, inputs, params, VerifySession(shared=shared))

    bumped = dict(inputs)
    x = np.asarray(bumped["x"]).copy()
    x.flat[0] = np.nextafter(x.flat[0], np.float32(np.inf))
    bumped["x"] = jnp.asarray(x)
    sc = VerifySession(shared=shared)
    run_program_cached(p, bumped, params, sc)
    # the first group's key moved, and so did every downstream key
    assert sc.stats.shared_group_hits == 0
    assert sc.stats.group_misses == len(p.schedule.groups)


def test_oracle_prep_rebinds_positionally_across_renamed_twins():
    p = _gemm("p", 64, 64, 32)
    t = rename_program(p, "z_")
    assert (graph_oracle_fingerprint(p.graph)
            == graph_oracle_fingerprint(t.graph))
    shared = SharedVerifyCache(64 * 1024 * 1024)
    calls = []

    def compute(g):
        calls.append(g.name)
        return prepare_oracle(g)

    prep_p = VerifySession(shared=shared).oracle_prep(p.graph, compute)
    sb = VerifySession(shared=shared)
    prep_t = sb.oracle_prep(t.graph, compute)
    assert calls == [p.graph.name]            # one oracle evaluation total
    assert sb.stats.shared_oracle_hits == 1
    # rebound to the twin's own names, values positionally identical
    assert set(prep_t[0]) == {n.name for n in t.graph.inputs()}
    assert set(prep_t[1]) == {n.name for n in t.graph.params()}
    np.testing.assert_array_equal(np.asarray(prep_p[2]["act"]),
                                  np.asarray(prep_t[2]["z_act"]))


# ----------------------------------------------------------------------
# SharedVerifyCache byte-LRU exactness
# ----------------------------------------------------------------------

def test_shared_cache_eviction_exact_under_stamp_churn():
    cache = SharedVerifyCache(max_bytes=400, shards=3)
    for i in range(4):
        assert cache.put(("group", f"k{i}"), [(0, _arr(i))])
    assert len(cache) == 4 and cache.total_bytes() == 400
    for _ in range(30):                       # pile up stale stamps
        cache.get(("group", "k0"))
        cache.get(("group", "k1"))
    assert cache.put(("group", "k4"), [(0, _arr(4))])   # evicts k2 (LRU)
    assert ("group", "k2") not in cache
    assert cache.put(("group", "k5"), [(0, _arr(5))])   # evicts k3
    assert ("group", "k3") not in cache
    for key in ("k0", "k1", "k4", "k5"):
        assert cache.get(("group", key)) is not None
    assert len(cache) == 4
    assert cache.total_bytes() == 400
    assert cache.evictions == 2


def test_shared_cache_refuses_oversized_and_refreshes_in_place():
    cache = SharedVerifyCache(max_bytes=400)
    assert not cache.put(("group", "big"), [(0, np.zeros(200, np.float32))])
    assert len(cache) == 0
    assert cache.put(("group", "a"), [(0, _arr(1))])
    # re-put under the same key replaces bytes, not duplicates them
    assert cache.put(("group", "a"), [(0, _arr(2)), (1, _arr(3))])
    assert len(cache) == 1 and cache.total_bytes() == 200
    got = cache.get(("group", "a"))
    np.testing.assert_array_equal(got[0][1], _arr(2))


def test_shared_cache_zero_cap_disables_writes():
    cache = SharedVerifyCache(max_bytes=0)
    assert not cache.put(("group", "a"), [(0, _arr(1))])
    assert cache.get(("group", "a")) is None
    assert cache.stats_dict()["entries"] == 0


# ----------------------------------------------------------------------
# per-session byte caps
# ----------------------------------------------------------------------

def test_session_group_memo_trims_fifo_over_byte_cap():
    s = VerifySession(max_group_bytes=250)
    for i, fp in enumerate(("a", "b", "c")):
        s._put_group(fp, [(0, _arr(i))])
    assert "a" not in s._groups               # oldest trimmed
    assert set(s._groups) == {"b", "c"}
    assert s._groups_total == 200
    # a single over-cap entry is kept (progress beats the cap)
    s2 = VerifySession(max_group_bytes=50)
    s2._put_group("only", [(0, _arr(9))])
    assert set(s2._groups) == {"only"}


def test_session_oracle_memo_trims_fifo_over_byte_cap():
    s = VerifySession(max_oracle_bytes=250)
    for i, key in enumerate(("a", "b", "c")):
        s._put_oracle(key, ([_arr(i)], [], []))
    assert set(s._oracle) == {"b", "c"}
    assert s._oracle_total == 200


# ----------------------------------------------------------------------
# engine integration: planner + backend equivalence
# ----------------------------------------------------------------------

def _twin_jobs(n_twins=2):
    ci = _gemm("lead", 128, 128, 64)
    bench = _gemm("lead", 1024, 1024, 256)
    jobs = [KernelJob("lead", ci, bench, tags=("gemm",))]
    for i in range(n_twins):
        jobs.append(KernelJob(f"tw{i}", rename_program(ci, f"t{i}_"),
                              rename_program(bench, f"t{i}_"),
                              tags=("gemm",)))
    assert len({program_exec_fingerprint(j.ci_program) for j in jobs}) == 1
    return jobs


def _views(report):
    return {r.job.name: (r.result.transform_log.to_list(),
                         r.result.optimized_time,
                         r.result.original_time,
                         round(r.result.speedup, 9))
            for r in report.results}


def test_planner_dedupes_twin_signatures_serial():
    with Forge(ForgeConfig(execution_backend="serial", workers=1,
                           verify_fastpath="on")) as forge:
        report = forge.optimize_batch(_twin_jobs())
    v = report.verify
    assert v is not None
    assert v.planner_signatures == 1          # one duplicated signature
    assert v.planner_deduped_jobs == 2        # both twins warm-started
    assert v.shared_oracle_hits >= 1
    assert v.shared_group_hits >= 1


def test_backend_equivalence_with_planning_on():
    jobs = _twin_jobs()
    views = {}
    for backend in ("serial", "thread", "process"):
        with Forge(ForgeConfig(execution_backend=backend, workers=2,
                               verify_fastpath="on")) as forge:
            views[backend] = _views(forge.optimize_batch(jobs))
    assert views["thread"] == views["serial"]
    assert views["process"] == views["serial"]


def test_planning_off_produces_identical_results():
    jobs = _twin_jobs()
    views = {}
    for label, overrides in (
            ("pr5", dict(shared_verify_cache_bytes=0,
                         batch_exec_planning=False)),
            ("shared", {})):
        with Forge(ForgeConfig(execution_backend="serial", workers=1,
                               verify_fastpath="on", **overrides)) as forge:
            views[label] = _views(forge.optimize_batch(jobs))
    assert views["shared"] == views["pr5"]


# ----------------------------------------------------------------------
# check mode: poisoned shared entries must fail loudly
# ----------------------------------------------------------------------

def _poison(cache, kind):
    poisoned = 0
    for shard in cache._shards:
        for key, rec in shard.entries.items():
            if key[0] != kind:
                continue
            if kind == "group":
                rec[1] = [(pos, v + 1) for pos, v in rec[1]]
            else:
                rec[1] = tuple([v + 1 for v in part] for part in rec[1])
            poisoned += 1
    return poisoned


def test_check_mode_detects_poisoned_shared_group():
    p = _gemm("p", 64, 64, 32)
    shared = SharedVerifyCache(64 * 1024 * 1024)
    inputs, params = make_inputs(p.graph), make_params(p.graph)
    run_program_cached(p, inputs, params, VerifySession(shared=shared))
    assert _poison(shared, "group") > 0
    checked = VerifySession(shared=shared, check_shared=True)
    with pytest.raises(VerifyFastpathDivergence):
        run_program_cached(p, inputs, params, checked)
    # without check mode the poisoned entry would have been adopted silently
    trusting = VerifySession(shared=shared)
    out = run_program_cached(p, inputs, params, trusting)
    assert trusting.stats.shared_group_hits >= 1 and out


def test_check_mode_detects_poisoned_shared_oracle():
    p = _gemm("p", 64, 64, 32)
    t = rename_program(p, "z_")
    shared = SharedVerifyCache(64 * 1024 * 1024)
    VerifySession(shared=shared).oracle_prep(p.graph, prepare_oracle)
    assert _poison(shared, "oracle") == 1
    checked = VerifySession(shared=shared, check_shared=True)
    with pytest.raises(VerifyFastpathDivergence):
        checked.oracle_prep(t.graph, prepare_oracle)
