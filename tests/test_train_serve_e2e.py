"""End-to-end behaviour: training reduces loss; serving generates; kernel-opt
integration writes the tuned registry; one real dry-run cell compiles."""

import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.model import RuntimeFlags, init_params
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer

TCFG = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=3,
                                         total_steps=60))

REPO = pathlib.Path(__file__).resolve().parents[1]
FLAGS = RuntimeFlags(remat=False, chunked_attention=False)


def test_training_reduces_loss():
    cfg = get_config("olmo-1b").reduced()
    t = Trainer(cfg, seq_len=64, global_batch=4, flags=FLAGS, seed=0,
                tcfg=TCFG)
    hist = t.train(40)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.95, (first, last)


def test_moe_training_reduces_loss():
    cfg = get_config("granite-moe-3b-a800m").reduced()
    t = Trainer(cfg, seq_len=48, global_batch=4, flags=FLAGS, seed=0,
                tcfg=TCFG)
    hist = t.train(30)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert all(np.isfinite(h["grad_norm"]) for h in hist)


def test_serve_engine_generates():
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("qwen3-8b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    engine = ServeEngine(cfg, params, max_len=32, slots=2, flags=FLAGS)
    rng = np.random.default_rng(0)
    for i in range(3):
        engine.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8),
                              max_new_tokens=6))
    done = engine.run()
    assert len(done) == 3
    assert all(len(r.generated) == 6 for r in done)
    # greedy decode is deterministic: same prompt -> same continuation
    e2 = ServeEngine(cfg, params, max_len=32, slots=2, flags=FLAGS)
    e2.submit(Request(rid=0, prompt=done[0].prompt.copy(), max_new_tokens=6))
    again = e2.run()
    assert again[0].generated == done[0].generated


def test_kernel_opt_writes_registry(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNED_KERNELS", str(tmp_path / "kernels.json"))
    import importlib
    import repro.kernels.ops as ops
    importlib.reload(ops)
    from repro.launch.kernel_opt import optimize_arch_kernels
    cfg = get_config("olmo-1b").reduced()
    results = optimize_arch_kernels(cfg, seq_len=512, batch=2, max_sites=2)
    assert any(v.get("speedup_vs_naive", 0) > 1 for v in results.values()
               if isinstance(v, dict) and "speedup_vs_naive" in v)
    data = json.loads((tmp_path / "kernels.json").read_text())
    assert "matmul_fused" in data and "flash_attention" in data
    monkeypatch.delenv("REPRO_TUNED_KERNELS")
    importlib.reload(ops)


@pytest.mark.slow
def test_one_real_dryrun_cell():
    """A real 512-device multi-pod compile in a subprocess (the cheapest cell)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = REPO / "results" / "test_cell.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-780m",
         "--shape", "long_500k", "--mesh", "multipod", "--out", str(out)],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 512
    assert rec["fits_hbm"]
    assert rec["collectives"]["total"] > 0
