"""Execution backends: config plumbing, serial/thread/process result
equivalence, observer-event marshalling, and history merge-back."""

import pickle

import pytest

from repro.aibench import build_program, load_specs
from repro.core import (EXECUTION_BACKENDS, Forge, ForgeConfig, KernelJob,
                        OptimizationEngine)
from repro.ir.fingerprint import program_canonical

SPECS = {s.name: s for s in load_specs()}


def _job(name, rename=None):
    s = SPECS[name]
    j = KernelJob(s.name,
                  build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
                  build_program(s.builder, s.dims("bench"), "naive",
                                meta=s.meta),
                  tags=tuple(s.tags), target_dtype=s.target_dtype,
                  rtol=s.rtol, atol=s.atol, meta=dict(s.meta))
    if rename:
        j.name = rename
    return j


# ----------------------------------------------------------------------
# config surface
# ----------------------------------------------------------------------

def test_backend_field_is_operational():
    """execution_backend must not shift cache keys: results are backend-
    equivalent by contract, so stores written under one backend replay
    under any other."""
    sigs = {ForgeConfig(execution_backend=b).policy_signature()
            for b in EXECUTION_BACKENDS}
    assert len(sigs) == 1
    names = {f.name for f in ForgeConfig.operational_fields()}
    assert "execution_backend" in names


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="execution_backend"):
        ForgeConfig(execution_backend="fork")
    with pytest.raises(ValueError, match="backend"):
        OptimizationEngine(backend="fork")


def test_config_with_backend_pickles():
    cfg = ForgeConfig(execution_backend="process", workers=2)
    back = pickle.loads(pickle.dumps(cfg))
    assert back == cfg
    assert back.policy_signature() == cfg.policy_signature()


def test_serial_backend_ignores_worker_count():
    """serial is the deterministic reference mode whatever workers says."""
    eng = OptimizationEngine(workers=4, backend="serial")
    assert type(eng._get_executor()).name == "serial"
    r = eng.run_batch([_job("gemm_bias_gelu")])
    assert len(r) == 1 and r[0].result.speedup > 1


def test_engine_close_idempotent():
    eng = OptimizationEngine(backend="thread")
    eng.close()
    eng.close()
    # a closed engine lazily rebuilds its executor
    assert eng.submit(_job("gemm_bias_gelu")).result.speedup > 1


# ----------------------------------------------------------------------
# serial == thread (cheap, in-process)
# ----------------------------------------------------------------------

def test_serial_thread_equivalence():
    names = ["gemm_bias_gelu", "gemm_swish_tanh_scale", "matmul_t_gelu"]
    serial = Forge(ForgeConfig(execution_backend="serial")) \
        .optimize_batch([_job(n) for n in names])
    thread = Forge(ForgeConfig(execution_backend="thread", workers=3)) \
        .optimize_batch([_job(n) for n in names])
    for a, b in zip(serial.results, thread.results):
        assert a.fingerprint == b.fingerprint
        assert a.result.transform_log.to_list() \
            == b.result.transform_log.to_list()
        assert a.result.optimized_time == pytest.approx(
            b.result.optimized_time)
        assert program_canonical(a.result.bench_program) \
            == program_canonical(b.result.bench_program)
    assert serial.stats.as_dict() == thread.stats.as_dict()


# ----------------------------------------------------------------------
# process backend (one spawn session exercises everything: equivalence,
# observer marshalling, transfer, replay, history merge-back)
# ----------------------------------------------------------------------

def test_process_backend_end_to_end():
    # family twins at different dims: the leader must seed the follower
    # through the transfer path *inside* the worker processes. The twin is
    # submitted twice so one phase holds two exact-identical followers —
    # the duplicate must coalesce (1 full run + 1 replay, cache_hit=True)
    # exactly like the in-process backends' _inflight path
    jobs = lambda: [_job("gemm_bias_gelu"), _job("matmul_t_gelu"),
                    _twin_job(), _twin_job("gemm_bias_gelu_twin2")]
    serial = Forge(ForgeConfig(execution_backend="serial"))
    sref = serial.optimize_batch(jobs())

    events = []

    class Obs:
        def on_stage_complete(self, job_name, record):
            events.append(("stage", job_name, record.stage))

        def on_job_complete(self, result):
            events.append(("job", result.job.name))

        def on_transfer(self, result):
            events.append(("transfer", result.job.name))

    with Forge(ForgeConfig(execution_backend="process", workers=2),
               observers=[Obs()]) as forge:
        prep = forge.optimize_batch(jobs())
        # second batch replays from the parent-held store
        prep2 = forge.optimize_batch(jobs())

        # results identical to the serial reference, job for job
        for a, b in zip(sref.results, prep.results):
            assert a.fingerprint == b.fingerprint
            assert a.result.transform_log.to_list() \
                == b.result.transform_log.to_list()
            assert a.result.optimized_time == pytest.approx(
                b.result.optimized_time)
            assert program_canonical(a.result.bench_program) \
                == program_canonical(b.result.bench_program)
            assert a.cache_hit == b.cache_hit
            assert a.transfer == b.transfer
        assert sref.stats.as_dict() == prep.stats.as_dict()

        # the family follower transferred, exactly as under serial
        assert prep.results[2].transfer == sref.results[2].transfer
        # the duplicate follower replayed (in-phase coalescing), as serial
        assert sref.results[3].cache_hit
        assert prep.results[3].cache_hit

        # observer events were marshalled back, not dropped
        stage_events = [e for e in events if e[0] == "stage"]
        job_events = [e for e in events if e[0] == "job"]
        assert len(job_events) == 8          # 4 jobs x 2 batches
        assert stage_events, "stage events must stream from workers"
        if prep.transfers:
            assert any(e[0] == "transfer" for e in events)

        # replay batch: everything hits the parent-held store
        assert all(r.cache_hit for r in prep2.results)

        # worker history deltas merged back into the shared history
        assert len(forge.history.records) == len(serial.history.records) > 0
        assert forge.history.snapshot_priors() \
            == serial.history.snapshot_priors()


def _twin_job(name="gemm_bias_gelu_twin"):
    """gemm_bias_gelu's builder at different dims — a family twin of the
    spec-dims job, so it exercises in-batch leader->follower transfer.
    Submitted twice (names differ, structure identical) it also exercises
    duplicate-exact-key coalescing within a single phase."""
    s = SPECS["gemm_bias_gelu"]
    dims = {k: max(64, v // 2) for k, v in s.dims("bench").items()}
    ci = {k: max(32, v // 2) for k, v in s.dims("ci").items()}
    return KernelJob(name,
                     build_program(s.builder, ci, "naive", meta=s.meta),
                     build_program(s.builder, dims, "naive", meta=s.meta),
                     tags=tuple(s.tags), target_dtype=s.target_dtype,
                     rtol=s.rtol, atol=s.atol, meta=dict(s.meta))


def test_process_backend_rejects_live_llm():
    class FakeLLM:
        pass

    from repro.core.pipeline import ForgePipeline

    pipe = ForgePipeline(llm=FakeLLM())
    eng = OptimizationEngine(pipeline=pipe, backend="process", workers=1)
    with pytest.raises(ValueError, match="LLM"):
        eng.run_batch([_job("gemm_bias_gelu")])
