"""Analyzer issue detection + planner ordering/skip logic."""

import pytest

from repro.core.analyzer import analyze
from repro.core.context import ProblemContext
from repro.core.issues import ISSUE_TO_STAGE, Issue, register_issue_type, stages_with_issues
from repro.core.llm import MockLLM
from repro.core.planner import DEFAULT_ORDER, HARD_DEPS, plan
from repro.ir import GraphBuilder
from repro.ir.cost import graph_flops
from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule
from repro.kb.loader import STAGES


def _program(dtype="float32", transpose_b=False, with_reduction=False,
             naive=True):
    b = GraphBuilder("p", dtype=dtype)
    x = b.input((1024, 512), name="x")
    w = b.param((2048, 512) if transpose_b else (512, 2048), name="w")
    mm = b.matmul(x, w, transpose_b=transpose_b, name="mm")
    last = b.gelu(mm, name="act")
    if with_reduction:
        last = b.reduce_sum(last, axes=(1,), name="red")
    g = b.done(last)
    sched = eager_schedule(g)
    if naive:
        for grp in sched.groups:
            if grp.root == "mm":
                grp.impl = "pallas_naive"
                grp.config = PallasConfig(128, 128, 32, num_stages=1)
    return KernelProgram("p", g, sched, original_flops=graph_flops(g))


CTX = ProblemContext(name="t")


def test_routing_table_complete():
    """Every issue type maps to exactly one known stage (paper Table 1)."""
    assert len(ISSUE_TO_STAGE) >= 30
    for typ, stage in ISSUE_TO_STAGE.items():
        assert stage in STAGES, (typ, stage)


def test_dynamic_issue_registration():
    register_issue_type("custom_vendor_issue", "gpu_specific")
    assert Issue("custom_vendor_issue", 3, "x").stage == "gpu_specific"
    with pytest.raises(ValueError):
        register_issue_type("bad", "not_a_stage")


def test_analyzer_detects_core_issues():
    issues = analyze(_program(dtype="float64", transpose_b=True), CTX)
    types = {i.type for i in issues}
    assert "dtype_float64" in types
    assert "manual_pointer_arithmetic" in types
    assert "uncoalesced_access" in types
    assert "unfused_kernels" in types
    assert "missing_boundary_check" in types


def test_analyzer_reduction_epilogue():
    issues = analyze(_program(with_reduction=False), CTX)
    assert "unfused_reduction_epilogue" not in {i.type for i in issues}
    # reduction directly after a contraction group is flagged once the
    # elementwise chain is inside the group
    p = _program(with_reduction=True)
    mm_grp = next(g for g in p.schedule.groups if g.root == "mm")
    act_grp = next(g for g in p.schedule.groups if g.root == "act")
    mm_grp.nodes.append("act")
    p.schedule.groups.remove(act_grp)
    issues = analyze(p, CTX)
    assert "unfused_reduction_epilogue" in {i.type for i in issues}


def test_severity_ordering_advisory():
    issues = analyze(_program(dtype="float64"), CTX)
    sevs = [i.severity for i in issues]
    assert sevs == sorted(sevs, reverse=True)


def test_plan_respects_hard_deps():
    issues = analyze(_program(dtype="float64", transpose_b=True,
                              with_reduction=True), CTX)
    order = plan(issues)
    pos = {s: i for i, s in enumerate(order)}
    for a, b in HARD_DEPS:
        if a in pos and b in pos:
            assert pos[a] < pos[b], (a, b, order)


def test_plan_skip_logic():
    """Stages without issues are not scheduled (paper §IV-A-b)."""
    p = _program()  # no f64, no transpose: dtype only from bf16 target
    issues = [i for i in analyze(p, CTX) if i.stage == "fusion"]
    order = plan(issues)
    assert order == ["fusion"]


def test_llm_planner_valid_order_used():
    issues = analyze(_program(dtype="float64"), CTX)
    active = stages_with_issues(issues)
    resp = ",".join(s for s in DEFAULT_ORDER if s in active)
    order = plan(issues, llm=MockLLM([resp]))
    assert order == [s for s in DEFAULT_ORDER if s in active]


def test_llm_planner_invalid_falls_back():
    issues = analyze(_program(dtype="float64", with_reduction=True), CTX)
    active = stages_with_issues(issues)
    # invalid: violates dtype->fusion dependency
    bad = MockLLM(["fusion,dtype_fix"])
    order = plan(issues, llm=bad)
    assert order == [s for s in DEFAULT_ORDER if s in active]


def test_llm_planner_exception_falls_back():
    issues = analyze(_program(dtype="float64"), CTX)
    active = stages_with_issues(issues)
    order = plan(issues, llm=MockLLM([]))  # raises on call
    assert order == [s for s in DEFAULT_ORDER if s in active]
