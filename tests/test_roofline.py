"""Roofline machinery: HLO parsing (walker + collective scan), term math."""

import textwrap

import pytest

from repro.roofline.analyze import (RooflineTerms, collective_bytes,
                                    from_record, parse_collectives)
from repro.roofline.hlo_walker import walk

HLO = textwrap.dedent("""
    HloModule test

    %body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
      %p = (s32[], f32[128,256]) parameter(0)
      %lhs = f32[128,64]{1,0} constant(0)
      %rhs = f32[64,256]{1,0} constant(0)
      %dot.1 = f32[128,256]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[128,256]{1,0} all-reduce(%dot.1), replica_groups={}
    }

    %cond.1 (p: (s32[], f32[128,256])) -> pred[] {
      %p = (s32[], f32[128,256]) parameter(0)
      %c = pred[] constant(false)
    }

    ENTRY %main.1 (a: f32[128,256]) -> f32[128,256] {
      %a = f32[128,256]{1,0} parameter(0)
      %ag = f32[512,256]{1,0} all-gather(%a), dimensions={0}
      %w = (s32[], f32[128,256]) while(%a), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"8"}}
    }
""")


def test_walker_trip_multiplies():
    res = walk(HLO)
    # dot: 2 * 128*256 * 64 = 4.19e6, x8 trips
    assert res.flops == pytest.approx(8 * 2 * 128 * 256 * 64)
    # collectives: all-gather once (512*256*4) + all-reduce x8 (128*256*4)
    assert res.coll_bytes == pytest.approx(512 * 256 * 4 + 8 * 128 * 256 * 4)
    assert res.coll_by_kind["all-reduce"] == pytest.approx(8 * 128 * 256 * 4)


def test_collective_scan_unrolled():
    per = collective_bytes(HLO)
    assert per["all-gather"] == 512 * 256 * 4
    assert per["all-reduce"] == 128 * 256 * 4  # unrolled scan counts once
    assert per["total"] == per["all-gather"] + per["all-reduce"]


def test_terms_math():
    rec = {
        "arch": "x", "shape": "train_4k", "mesh": "single", "n_devices": 256,
        "cost": {"flops": 1.97e12, "bytes": 8.19e11},
        "collectives": {"total": 5e10},
        "model_flops": 1.97e12 * 256 * 0.5,
    }
    t = from_record(rec)
    assert t.t_compute == pytest.approx(1.97e12 * 256 / (256 * 197e12))
    assert t.t_memory == pytest.approx(8.19e11 * 256 / (256 * 819e9))
    assert t.t_collective == pytest.approx(5e10 / 50e9)
    assert t.dominant == "memory"
    assert t.useful_ratio == pytest.approx(0.5)
    assert 0 < t.roofline_fraction < 1


def test_dominant_identification():
    base = {"arch": "x", "shape": "s", "mesh": "single", "n_devices": 4,
            "model_flops": 1e12}
    t = from_record({**base, "cost": {"flops": 1e15, "bytes": 1e3},
                     "collectives": {"total": 1e3}})
    assert t.dominant == "compute"
    t = from_record({**base, "cost": {"flops": 1e3, "bytes": 1e3},
                     "collectives": {"total": 1e14}})
    assert t.dominant == "collective"
