"""Learned search (PR 7): the graded family-key ladder, trajectory-mined
priors (with the bit-exact ``counts`` compatibility mode), append-only JSONL
history persistence, and the total-order warm-start comparator."""

import json

import pytest

from repro.core.history import History, PatternStats, PriorSnapshot
from repro.core.job_codec import decode_priors, encode_priors
from repro.core.result_store import ResultStore
from repro.core.stage_scheduler import WarmStartProposer
from repro.core.proposers import Candidate
from repro.ir import GraphBuilder
from repro.ir.cost import graph_flops
from repro.ir.fingerprint import (FAMILY_LADDER_TIERS, dims_log_distance,
                                  fingerprint_family,
                                  fingerprint_family_ladder, job_dims_vector)
from repro.ir.schedule import KernelProgram, eager_schedule


def _gemm(m, n, k, name="g"):
    b = GraphBuilder(name)
    x = b.input((m, k), name="x")
    w = b.param((k, n), name="w")
    g = b.done(b.gelu(b.matmul(x, w, name="mm"), name="act"))
    return KernelProgram(name, g, eager_schedule(g),
                        original_flops=graph_flops(g))


def _ladder(m, n, k):
    p = _gemm(m, n, k)
    return fingerprint_family_ladder(p, p, "tpu_v5e", "bfloat16", ("gemm",))


# ----------------------------------------------------------------------
# Family-key ladder (ir/fingerprint.py)
# ----------------------------------------------------------------------

def test_ladder_tiers_finest_first_rank_matches_family():
    lad = _ladder(512, 512, 256)
    assert tuple(t for t, _ in lad) == FAMILY_LADDER_TIERS == \
        ("dims", "aspect", "rank")
    p = _gemm(512, 512, 256)
    # the coarsest tier is byte-identical to the pre-ladder family key, so
    # stores recorded before the ladder existed stay reachable
    assert lad[-1][1] == fingerprint_family(p, p, "tpu_v5e", "bfloat16",
                                            ("gemm",))


def test_ladder_collision_grades_with_similarity():
    base = dict(_ladder(512, 512, 256))
    same = dict(_ladder(512, 512, 256))
    scaled = dict(_ladder(1024, 1024, 512))      # uniform 2x: same aspect
    other = dict(_ladder(512, 256, 256))         # different aspect
    assert same == base
    assert scaled["dims"] != base["dims"]
    assert scaled["aspect"] == base["aspect"]
    assert scaled["rank"] == base["rank"]
    assert other["dims"] != base["dims"]
    assert other["aspect"] != base["aspect"]
    assert other["rank"] == base["rank"]


def test_dims_vector_and_log_distance():
    p1, p2 = _gemm(512, 512, 256), _gemm(1024, 1024, 512)
    v1 = job_dims_vector(p1, p1)
    v2 = job_dims_vector(p2, p2)
    assert dims_log_distance(v1, v1) == 0.0
    assert 0.0 < dims_log_distance(v1, v2) < float("inf")
    assert dims_log_distance(v1, None) == float("inf")
    assert dims_log_distance(v1, v1[:-1]) == float("inf")


# ----------------------------------------------------------------------
# Graded neighbor selection (core/result_store.py)
# ----------------------------------------------------------------------

QUERY_LADDER = (("dims", "D"), ("aspect", "A"), ("rank", "R"))


def _entry(log_len=1, orig=2.0, opt=1.0):
    return {"transform_log": [{"stage": "fusion", "pattern_id": f"p{i}",
                               "description": "d"} for i in range(log_len)],
            "original_time": orig, "optimized_time": opt}


def test_ladder_members_same_dims_beats_aspect_beats_rank():
    store = ResultStore()
    # deliberately inserted coarsest-first: recency/insertion order must
    # never beat tier order
    store.put("k_rank", _entry(), family="R",
              ladder=(("dims", "D3"), ("aspect", "A3"), ("rank", "R")),
              dims=(400,))
    store.put("k_aspect", _entry(), family="R",
              ladder=(("dims", "D2"), ("aspect", "A"), ("rank", "R")),
              dims=(200,))
    store.put("k_dims", _entry(), family="R",
              ladder=QUERY_LADDER, dims=(100,))
    members = store.ladder_members(QUERY_LADDER, dims=(100,))
    assert [k for k, _ in members] == ["k_dims", "k_aspect", "k_rank"]


def test_ladder_members_within_tier_tie_breaks_are_total():
    # all three sit at the same (rank) tier and the same dim distance:
    # longer transform log wins, then higher speedup, then key ascending
    lad = (("rank", "R"),)
    for order in (("a", "b", "c"), ("c", "b", "a")):
        store = ResultStore()
        entries = {
            "a": _entry(log_len=2, orig=2.0, opt=1.0),
            "b": _entry(log_len=1, orig=4.0, opt=1.0),
            "c": _entry(log_len=1, orig=2.0, opt=1.0),
        }
        for key in order:
            store.put(key, entries[key], family="R",
                      ladder=lad, dims=(100,))
        members = store.ladder_members(lad, dims=(100,))
        assert [k for k, _ in members] == ["a", "b", "c"], order


def test_ladder_members_closer_dims_rank_first_within_tier():
    store = ResultStore()
    store.put("far", _entry(), family="R", ladder=(("rank", "R"),),
              dims=(400,))
    store.put("near", _entry(), family="R", ladder=(("rank", "R"),),
              dims=(128,))
    members = store.ladder_members((("rank", "R"),), dims=(100,))
    assert [k for k, _ in members] == ["near", "far"]


def test_pre_ladder_entries_surface_at_rank_tier():
    """Entries put with only ``family=`` (the pre-PR call shape) surface at
    the coarsest tier — ranked last (unknown dims -> distance inf) but
    never dropped."""
    store = ResultStore()
    store.put("old", _entry(), family="R")
    store.put("new", _entry(), family="R", ladder=QUERY_LADDER, dims=(100,))
    members = store.ladder_members(QUERY_LADDER, dims=(100,))
    assert [k for k, _ in members] == ["new", "old"]
    # and the legacy family API still sees both
    assert len(store.family_members("R")) == 2


# ----------------------------------------------------------------------
# Mined priors + counts compatibility (core/history.py)
# ----------------------------------------------------------------------

def _seed_history(hist):
    hist.record("p1", "fusion", "pat_a", True, 2.0, 1, tried=["pat_a"])
    hist.record("p2", "fusion", "pat_a", True, 4.0, 2,
                tried=["pat_b", "pat_a"])
    hist.record("p3", "fusion", "pat_b", False, None, 5,
                tried=["pat_b"])
    hist.record("p4", "autotuning", "pat_c", True, 1.5, 1,
                tried=["pat_c"])


def test_counts_snapshot_is_bitexact_legacy_dict():
    hist = History()
    _seed_history(hist)
    snap = hist.snapshot_priors()
    assert snap.policy == "counts"
    # the Mapping view IS the legacy flat success-count dict
    assert dict(snap) == {"pat_a": 2, "pat_c": 1}
    assert snap == dict(hist.success_counts)
    # counts mode carries no mined stats: score is always 0
    assert snap.score("fusion", "pat_a") == 0.0


def test_mined_snapshot_scores_rank_patterns():
    hist = History()
    _seed_history(hist)
    snap = hist.snapshot_priors("mined")
    a = snap.stats("fusion", "pat_a")
    b = snap.stats("fusion", "pat_b")
    assert (a.attempts, a.successes) == (2, 2)
    assert (b.attempts, b.successes) == (2, 0)
    assert snap.score("fusion", "pat_a") > snap.score("fusion", "pat_b")
    assert snap.score("fusion", "never_tried") == 0.0
    # stage-conditioned: pat_c's wins don't leak into fusion
    assert snap.stats("fusion", "pat_c") is None


def test_mined_snapshot_is_record_order_independent():
    h1, h2 = History(), History()
    _seed_history(h1)
    hist_rev = History()
    hist_rev.merge_records(list(reversed(h1.records)))
    _seed_history(h2)
    assert h2.snapshot_priors("mined") == hist_rev.snapshot_priors("mined")


def test_empty_pattern_id_records_not_counted():
    hist = History()
    hist.record("p", "fusion", "", True, 2.0, 1)
    hist.merge_records([{"problem": "q", "stage": "fusion", "pattern_id": "",
                         "improved": True, "speedup": 2.0, "iterations": 1}])
    assert dict(hist.snapshot_priors()) == {}
    assert hist.snapshot_priors("mined").stats("fusion", "") is None


def test_prior_snapshot_wire_roundtrip():
    hist = History()
    _seed_history(hist)
    for policy in ("counts", "mined"):
        snap = hist.snapshot_priors(policy)
        clone = decode_priors(encode_priors(snap))
        assert isinstance(clone, PriorSnapshot)
        assert clone == snap
    # plain-dict priors (legacy wire) roundtrip as dicts
    assert decode_priors(encode_priors({"pat": 3})) == {"pat": 3}


def test_pattern_stats_roundtrip():
    s = PatternStats()
    s.attempts, s.successes, s.log_speedup_sum, s.iterations_sum = 3, 2, 1.5, 4
    assert PatternStats.from_dict(s.to_dict()) == s


# ----------------------------------------------------------------------
# Append-only JSONL history (satellite)
# ----------------------------------------------------------------------

def test_history_appends_jsonl_lines(tmp_path):
    path = tmp_path / "hist.jsonl"
    hist = History(path)
    _seed_history(hist)
    lines = [ln for ln in path.read_text().splitlines() if ln.strip()]
    assert len(lines) == 4
    assert all(isinstance(json.loads(ln), dict) for ln in lines)
    reloaded = History(path)
    assert reloaded.records == hist.records
    assert dict(reloaded.success_counts) == dict(hist.success_counts)
    assert reloaded.snapshot_priors("mined") == hist.snapshot_priors("mined")


def test_history_migrates_legacy_json_file(tmp_path):
    path = tmp_path / "hist.json"
    legacy = [{"problem": "p", "stage": "fusion", "pattern_id": "pat_a",
               "improved": True, "speedup": 2.0, "iterations": 1}]
    path.write_text(json.dumps({"records": legacy}))
    hist = History(path)
    assert hist.records == legacy
    assert hist.success_counts["pat_a"] == 1
    # first write rewrites the whole file as JSONL (old + new records)
    hist.record("q", "fusion", "pat_b", True, 3.0, 2)
    lines = [ln for ln in path.read_text().splitlines() if ln.strip()]
    assert len(lines) == 2
    assert History(path).records == hist.records


def test_legacy_records_without_tried_degrade_to_accepted_only():
    hist = History()
    hist.merge_records([{"problem": "p", "stage": "fusion",
                         "pattern_id": "pat_a", "improved": True,
                         "speedup": 2.0, "iterations": 1}])
    s = hist.snapshot_priors("mined").stats("fusion", "pat_a")
    assert (s.attempts, s.successes) == (1, 1)


# ----------------------------------------------------------------------
# Total-order warm-start comparator (satellite)
# ----------------------------------------------------------------------

class _StubProposer:
    def __init__(self, stage, cands):
        self.stage = stage
        self.kb = None
        self.ctx = None
        self._cands = cands

    def candidates(self, program, issues, trajectory):
        return iter(list(self._cands))


def _cands(*pattern_ids):
    return [Candidate(thought="", description=p, transform=lambda x: x,
                      pattern_id=p) for p in pattern_ids]


def test_counts_policy_ordering_is_legacy_stable_sort():
    priors = {"pat_b": 3, "pat_c": 1}
    cands = _cands("pat_a", "pat_b", "pat_c", "pat_d")
    warm = WarmStartProposer(_StubProposer("fusion", cands), priors)
    got = [c.pattern_id for c in warm.candidates(None, [], [])]
    legacy = [c.pattern_id for c in
              sorted(cands, key=lambda c: -priors.get(c.pattern_id, 0))]
    assert got == legacy == ["pat_b", "pat_c", "pat_a", "pat_d"]


def test_mined_policy_total_order_prior_then_cost_then_pattern_id():
    hist = History()
    _seed_history(hist)
    snap = hist.snapshot_priors("mined")
    costs = {"pat_x": (2.0, 20.0), "pat_y": (1.0, 10.0),
             "pat_z": (1.0, 10.0), "pat_a": (9.0, 9.0)}

    def estimator(cand, program):
        return costs[cand.pattern_id]

    cands = _cands("pat_z", "pat_x", "pat_y", "pat_a")
    warm = WarmStartProposer(_StubProposer("fusion", cands), snap,
                             policy="mined", estimator=estimator)
    # pat_a has the only positive mined score (despite the worst cost
    # estimate); x/y/z tie at score 0 and fall back to cost estimate, then
    # pattern_id
    got = [c.pattern_id for c in warm.candidates(None, [], [])]
    assert got == ["pat_a", "pat_y", "pat_z", "pat_x"]


def test_mined_policy_without_estimator_or_priors_is_passthrough():
    cands = _cands("pat_b", "pat_a")
    warm = WarmStartProposer(
        _StubProposer("fusion", cands),
        PriorSnapshot({}, {}, policy="mined"), policy="mined")
    assert [c.pattern_id for c in warm.candidates(None, [], [])] \
        == ["pat_b", "pat_a"]


def test_mined_policy_ordering_independent_of_input_order():
    hist = History()
    _seed_history(hist)
    snap = hist.snapshot_priors("mined")

    def estimator(cand, program):
        return (1.0, 1.0)

    orders = []
    for perm in (("pat_a", "pat_b", "pat_c"), ("pat_c", "pat_b", "pat_a")):
        warm = WarmStartProposer(_StubProposer("fusion", _cands(*perm)),
                                 snap, policy="mined", estimator=estimator)
        orders.append([c.pattern_id for c in warm.candidates(None, [], [])])
    assert orders[0] == orders[1]


def test_invalid_prior_policy_rejected():
    with pytest.raises(ValueError, match="prior policy"):
        PriorSnapshot({}, {}, policy="nope")
