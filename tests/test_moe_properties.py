"""Property tests: MoE dispatch invariants + int8 KV quantization."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig, get_config
from repro.models import layers as L


@settings(max_examples=12, deadline=None)
@given(tokens=st.sampled_from([8, 16, 32]), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 50))
def test_moe_routing_invariants(tokens, e, k, seed):
    """Slots stay within capacity; every kept route lands on its top-k expert;
    gates are a softmax (sum to 1)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              moe=MoEConfig(num_experts=e, top_k=k))
    p = L.init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32)
    xt = jax.random.normal(jax.random.PRNGKey(seed + 1), (tokens, cfg.d_model))
    flat_e, slot, keep, gates, cap = L.moe_route(cfg, p, xt, 1.25)
    assert int(jnp.max(slot)) < cap
    assert gates.shape == (tokens, k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    # occupancy per expert never exceeds capacity among kept routes
    occ = np.zeros(e, np.int64)
    fe, kp = np.asarray(flat_e), np.asarray(keep)
    for i in range(fe.shape[0]):
        if kp[i]:
            occ[fe[i]] += 1
    assert (occ <= cap).all()


@settings(max_examples=10, deadline=None)
@given(capacity_factor=st.sampled_from([4.0, 8.0]), seed=st.integers(0, 30))
def test_moe_dropfree_matches_dense_mixture(capacity_factor, seed):
    """With generous capacity, grouped dispatch equals the explicit dense
    mixture-of-experts computation."""
    cfg = get_config("grok-1-314b").reduced()  # 4 experts, top-2
    p = L.init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model))
    got = L.apply_moe(cfg, p, x, capacity_factor=capacity_factor)
    # dense reference: all experts on all tokens, combine by gates
    xt = x.reshape(-1, cfg.d_model)
    logits = (xt @ p["router"]).astype(jnp.float32)
    gv, idx = jax.lax.top_k(logits, cfg.moe.top_k)
    gates = jax.nn.softmax(gv, -1)
    hi = jnp.einsum("td,edf->etf", xt, p["wi"])
    hg = jnp.einsum("td,edf->etf", xt, p["wg"])
    out_e = jnp.einsum("etf,efd->etd", jax.nn.silu(hi) * hg, p["wo"])
    t = xt.shape[0]
    want = jnp.zeros_like(xt)
    for kk in range(cfg.moe.top_k):
        sel = out_e[idx[:, kk], jnp.arange(t)]          # [T, D]
        want = want + sel * gates[:, kk, None]
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100))
def test_kv_int8_quant_roundtrip_error_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 8, 2, 16)) * 1.5
    q = L._kv_quant(x, jnp.int8)
    back = L._kv_dequant(q)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(back) - np.asarray(np.clip(x, -127/32, 127/32)))
    assert err.max() <= 0.5 / L.KV_Q_SCALE + 1e-6
