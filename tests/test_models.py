"""Per-arch smoke tests (reduced configs): forward/loss shapes + NaN gates,
prefill/decode consistency, and family-specific behaviors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, all_configs, get_config
from repro.configs.shapes import SHAPES, applicability
from repro.models import (decode_step, forward, init_cache, init_params,
                          lm_loss, prefill)
from repro.models.model import RuntimeFlags

# drop-free MoE capacity so forward / prefill+decode agree exactly
# (capacity dropping at 1.25 is exercised by the training-path tests)
FLAGS = RuntimeFlags(use_pallas=False, chunked_attention=False, remat=False,
                     moe_capacity_factor=8.0)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=48, seed=1):
    tk = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)
    batch = {"tokens": tk, "labels": tk}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_frames, cfg.d_model)) * 0.1
    if cfg.num_prefix_embeds:
        batch["tokens"] = tk[:, :S - cfg.num_prefix_embeds]
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.num_prefix_embeds, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss(arch):
    cfg = get_config(arch).reduced()
    B, S = 2, 48
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg, B, S)
    logits, aux = forward(cfg, params, batch, FLAGS)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()
    loss = lm_loss(cfg, params, batch, FLAGS)
    assert np.isfinite(float(loss))
    if cfg.moe:
        assert float(aux) > 0  # load-balancing loss present


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_no_nans(arch):
    from repro.optim import adamw
    from repro.train.train_step import TrainConfig, make_train_step
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY, jnp.float32)
    opt = adamw.init(adamw.AdamWConfig(), params)
    step = make_train_step(cfg, FLAGS, TrainConfig())
    batch = _batch(cfg)
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    B, S = 2, 48
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg, B, S)
    logits_full, _ = forward(cfg, params, batch, FLAGS)
    want = np.asarray(logits_full[:, -1])

    got, _ = prefill(cfg, params, batch, FLAGS)
    err = np.abs(np.asarray(got) - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 2e-5, f"{arch} prefill drift {err}"

    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, :-1]
    _, cache = prefill(cfg, params, b2, FLAGS)
    if cfg.family in ("dense", "moe", "encdec"):
        pad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        cache = {k: (pad(v) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    logits_step, _ = decode_step(cfg, params, cache,
                                 batch["tokens"][:, -1:], S - 1, FLAGS)
    err2 = np.abs(np.asarray(logits_step) - want).max() / (np.abs(want).max() + 1e-9)
    assert err2 < 5e-4, f"{arch} decode drift {err2}"


def test_long_context_applicability():
    cfgs = all_configs()
    runs = {a for a, c in cfgs.items() if applicability(c, "long_500k")[0]}
    assert runs == {"mamba2-780m", "recurrentgemma-2b"}
    ok, reason = applicability(cfgs["qwen2-7b"], "long_500k")
    assert not ok and "SKIP" in reason


def test_window_attention_caps_cache():
    cfg = get_config("recurrentgemma-2b").reduced()
    cache = init_cache(cfg, batch=2, max_len=512)
    for i, kind in enumerate(["rglru", "rglru", "attn"]):
        entry = cache[f"layer_{i}"]
        if kind == "attn":
            assert entry["k"].shape[1] == cfg.window  # rolling window only
        else:
            assert "h" in entry and "conv" in entry


def test_mamba_decode_state_is_constant_size():
    cfg = get_config("mamba2-780m").reduced()
    c1 = init_cache(cfg, 2, 512)
    c2 = init_cache(cfg, 2, 524288)
    s1 = sum(x.size for x in jax.tree.leaves(c1))
    s2 = sum(x.size for x in jax.tree.leaves(c2))
    assert s1 == s2  # O(1) in context length — why long_500k is runnable


def test_chunked_attention_matches_full():
    cfg = get_config("qwen3-8b").reduced()
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg, 2, 64)
    full, _ = forward(cfg, params, batch,
                      RuntimeFlags(chunked_attention=False, remat=False))
    chunked, _ = forward(cfg, params, batch,
                         RuntimeFlags(chunked_attention=True, remat=False))
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_use_pallas_path_matches_jnp():
    """The Pallas-kernel execution path agrees with the jnp path (the
    framework-level kernel integration)."""
    cfg = get_config("qwen3-8b").reduced()
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg, 1, 32)
    jnp_out, _ = forward(cfg, params, batch,
                         RuntimeFlags(use_pallas=False, remat=False,
                                      chunked_attention=False))
    pl_out, _ = forward(cfg, params, batch,
                        RuntimeFlags(use_pallas=True, remat=False,
                                     chunked_attention=False))
    np.testing.assert_allclose(np.asarray(pl_out), np.asarray(jnp_out),
                               rtol=5e-3, atol=5e-3)


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    c = get_config("grok-1-314b")
    assert (c.num_layers, c.d_model, c.num_heads, c.kv_heads, c.d_ff,
            c.vocab) == (64, 6144, 48, 8, 32768, 131072)
    assert c.moe.num_experts == 8 and c.moe.top_k == 2
    c = get_config("qwen2-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.kv_heads, c.d_ff,
            c.vocab) == (28, 3584, 28, 4, 18944, 152064)
    assert c.qkv_bias
    c = get_config("mamba2-780m")
    assert c.ssm.d_state == 128 and c.num_layers == 48 and c.d_model == 1536
    c = get_config("recurrentgemma-2b")
    assert c.window == 2048 and c.block_pattern == ("rglru", "rglru", "attn")
    assert c.kv_heads == 1
    c = get_config("whisper-small")
    assert c.encoder_layers == 12 and c.vocab == 51865
