"""Harness separation (paper §VII-a): the optimizer mutates only the kernel
program; input generation, seeding, oracle computation and dispatch are owned
by the trusted runner. Adversarial candidates must not be able to fake
correctness or speedups."""

import jax.numpy as jnp
import numpy as np

from repro.core.cover import CoVeRAgent
from repro.core.pipeline import ForgePipeline
from repro.core.proposers import BaseProposer, Candidate
from repro.core.verify import compile_and_verify
from repro.ir import GraphBuilder
from repro.ir.cost import CostModel, graph_flops
from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule
from repro.kb.loader import load_default

KB = load_default()
CM = CostModel()


def _problem():
    def build(M, N, K):
        b = GraphBuilder("p")
        x = b.input((M, K), name="x")
        w = b.param((K, N), name="w")
        mm = b.matmul(x, w, name="mm")
        g = b.done(b.gelu(mm, name="act"))
        sched = eager_schedule(g)
        for grp in sched.groups:
            if grp.root == "mm":
                grp.impl = "pallas_naive"
                grp.config = PallasConfig(128, 128, 32, num_stages=1)
        return KernelProgram("p", g, sched, original_flops=graph_flops(g))
    return build(256, 256, 128), build(4096, 4096, 1024)


def test_tiny_graph_swap_fails_correctness():
    """Adversarial: replace the computation with a cheap wrong one — modeled
    time plummets, but the trusted oracle comparison rejects it."""
    ci, bench = _problem()
    ctx = ForgePipeline()._prepare_ctx("t", ci, ("gemm",), "bfloat16",
                                       1e-2, 1e-3, {})

    def cheat(p: KernelProgram) -> KernelProgram:
        b = GraphBuilder("p")
        x = b.input(p.graph.node("x").shape, name="x")
        w = b.param(p.graph.node("w").shape, name="w")
        # "optimized": just pass a slice of x through — nearly free
        g = b.done(b.relu(b.matmul(x, w, name="mm"), name="act"))
        g.node("act").op = "identity"
        p2 = KernelProgram("p", g, eager_schedule(g),
                           original_flops=p.original_flops)
        return p2

    rep = compile_and_verify(cheat(ci), cheat(bench), CM.program_time(bench),
                             ctx, KB)
    assert not rep.ok
    assert rep.level == "correctness"


def test_flop_accounting_cannot_be_inflated():
    """Adversarial: a candidate cannot inflate original_flops to game the
    TFLOPS metric — the perf gate compares modeled *time*, and speedups are
    derived from the incumbent's time, never from candidate-claimed FLOPs."""
    ci, bench = _problem()
    ctx = ForgePipeline()._prepare_ctx("t", ci, ("gemm",), "bfloat16",
                                       1e-2, 1e-3, {})

    def inflate(p):
        p = p.copy()
        p.original_flops *= 1e6
        return p

    incumbent = CM.program_time(bench)
    rep = compile_and_verify(inflate(ci), inflate(bench), incumbent, ctx, KB)
    assert not rep.ok and rep.level == "performance"


def test_oracle_owned_by_harness_not_candidate():
    """The oracle outputs live in the context, computed before any candidate
    exists; transforms receive only the program."""
    ci, bench = _problem()
    pipe = ForgePipeline()
    ctx = pipe._prepare_ctx("t", ci, ("gemm",), "bfloat16", 1e-2, 1e-3, {})
    before = {k: np.asarray(v).copy() for k, v in ctx.oracle_outputs.items()}
    pipe.optimize("t", ci, bench, tags=("gemm",))
    after = ctx.oracle_outputs
    for k in before:
        np.testing.assert_array_equal(before[k], np.asarray(after[k]))


class EvasiveProposer(BaseProposer):
    """Tries the reflection-evasion analogue: emit a program whose outputs
    are literally the oracle values baked in as constants."""
    stage = "discovery"

    def __init__(self, kb, ctx):
        super().__init__(kb, ctx)

    def candidates(self, program, issues, trajectory):
        oracle = self.ctx.oracle_outputs  # adversary peeks at ctx

        def bake(p: KernelProgram) -> KernelProgram:
            b = GraphBuilder("p")
            x = b.input(p.graph.node("x").shape, name="x")
            w = b.param(p.graph.node("w").shape, name="w")
            mm = b.matmul(x, w, name="mm")
            g = b.done(b.gelu(mm, name="act"))
            return KernelProgram("p", g, eager_schedule(g),
                                 original_flops=p.original_flops)
        yield Candidate("bake oracle", "evade", bake, "evil")


def test_evasion_cannot_beat_perf_gate():
    """Even a correct-by-construction candidate must be *faster on the bench
    program's modeled execution* — there is no way to shortcut the metric
    because the runner executes the program it was given."""
    ci, bench = _problem()
    ctx = ForgePipeline()._prepare_ctx("t", ci, ("gemm",), "bfloat16",
                                       1e-2, 1e-3, {})
    agent = CoVeRAgent("discovery", EvasiveProposer(KB, ctx), KB,
                       max_iterations=2)
    res = agent.run(ci, bench, [], ctx, CM.program_time(bench), CM)
    # the baked program is mathematically identical but scheduled eagerly
    # with XLA impls — the cost model sees through it; no free speedup.
    assert not res.improved or res.report.speedup < 100
