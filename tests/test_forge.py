"""Forge facade: optimize/optimize_batch report shape, observer callbacks
(stage/job/transfer), config plumbing into pipeline + engine, and driver
parity with the old direct-engine wiring."""

import pytest

from repro.aibench import build_program, load_specs
from repro.forge import (Forge, ForgeConfig, ForgeObserver, KernelJob,
                         OptimizationReport)

SPECS = {s.name: s for s in load_specs()}


def _job(name):
    s = SPECS[name]
    return KernelJob(s.name,
                     build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
                     build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
                     tags=tuple(s.tags), target_dtype=s.target_dtype,
                     rtol=s.rtol, atol=s.atol, meta=dict(s.meta))


class Recorder(ForgeObserver):
    def __init__(self):
        self.stages = []
        self.jobs = []
        self.transfers = []

    def on_stage_complete(self, job_name, record):
        self.stages.append((job_name, record.stage))

    def on_job_complete(self, result):
        self.jobs.append(result.job.name)

    def on_transfer(self, result):
        self.transfers.append(result.job.name)


def test_optimize_returns_single_result_report():
    forge = Forge(ForgeConfig())
    report = forge.optimize(_job("gemm_bias_gelu"))
    assert isinstance(report, OptimizationReport)
    assert len(report) == 1
    assert report.result.result.speedup > 1
    assert report.config is forge.config
    assert report.geomean_speedup == pytest.approx(report.result.result.speedup)


def test_optimize_batch_submission_order_and_report():
    names = ["gemm_bias_gelu", "matmul_t_gelu"]
    forge = Forge(ForgeConfig())
    report = forge.optimize_batch([_job(n) for n in names])
    assert [r.job.name for r in report] == names
    assert set(report.speedups) == set(names)
    d = report.as_dict()
    assert d["policy_signature"] == forge.config.policy_signature()
    assert [j["name"] for j in d["jobs"]] == names
    assert d["stats"]["jobs"] == 2
    assert "geomean" in report.summary() or "jobs" in report.summary()


def test_observers_fire_for_search_replay_and_transfer():
    obs = Recorder()
    forge = Forge(ForgeConfig(), observers=[obs])
    forge.optimize(_job("gemm_bias_gelu"))
    assert obs.jobs == ["gemm_bias_gelu"]
    assert obs.stages and all(n == "gemm_bias_gelu" for n, _ in obs.stages)
    n_search_stages = len(obs.stages)

    # cache replay also emits stage events (one per accepted transform)
    forge.optimize(_job("gemm_bias_gelu"))
    assert obs.jobs == ["gemm_bias_gelu"] * 2
    assert len(obs.stages) > n_search_stages
    assert obs.transfers == []


def test_on_transfer_fires_for_family_warm_start():
    from repro.ir import GraphBuilder
    from repro.ir.cost import graph_flops
    from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule

    def gemm(name, m, n, k):
        b = GraphBuilder(name)
        x = b.input((m, k), name="x")
        w = b.param((k, n), name="w")
        g = b.done(b.gelu(b.matmul(x, w, name="mm"), name="act"))
        sched = eager_schedule(g)
        for grp in sched.groups:
            if grp.root == "mm":
                grp.impl = "pallas_naive"
                grp.config = PallasConfig(128, 128, 32, num_stages=1)
        return KernelProgram(name, g, sched, original_flops=graph_flops(g))

    def job(m, n, k):
        return KernelJob("g", gemm("g", min(m, 256), min(n, 256), min(k, 128)),
                         gemm("g", m, n, k), tags=("gemm",))

    obs = Recorder()
    forge = Forge(ForgeConfig(), observers=[obs])
    forge.optimize(job(2048, 1024, 512))
    assert obs.transfers == []
    res = forge.optimize(job(4096, 2048, 1024)).result
    assert res.transfer
    assert obs.transfers == ["g"]
    assert obs.jobs == ["g", "g"]


def test_add_observer_and_plain_object_observer():
    seen = []

    class Plain:                      # duck-typed: only one hook
        def on_job_complete(self, result):
            seen.append(result.job.name)

    forge = Forge(ForgeConfig()).add_observer(Plain())
    forge.optimize(_job("gemm_bias_gelu"))
    assert seen == ["gemm_bias_gelu"]


def test_config_reaches_pipeline_and_engine():
    cfg = ForgeConfig(max_iterations=3, best_of_k=2, workers=2,
                      cache_max_entries=32)
    forge = Forge(cfg)
    assert forge.pipeline.config is cfg
    assert forge.pipeline.T == 3 and forge.pipeline.k == 2
    assert forge.engine.workers == 2
    assert forge.engine.cache.max_entries == 32
    assert forge.pipeline.policy_signature() == cfg.policy_signature()


def test_engine_from_config_shim():
    from repro.core import OptimizationEngine
    cfg = ForgeConfig(workers=3, cache_max_entries=64)
    eng = OptimizationEngine(config=cfg)
    assert eng.workers == 3
    assert eng.cache.max_entries == 64
    assert eng.pipeline.config is cfg
    # explicit kwargs always beat config values — a migrating caller must
    # not silently lose their concurrency/cache-size setting
    eng2 = OptimizationEngine(config=cfg, workers=8, cache_max_entries=16)
    assert eng2.workers == 8
    assert eng2.cache.max_entries == 16


def test_unknown_spec_name_raises_not_falls_back():
    with pytest.raises(KeyError, match="unknown TPU generation"):
        Forge(ForgeConfig(spec_name="tpu_v99"))


def test_custom_spec_object_still_honored():
    import dataclasses as dc
    from repro.core import ForgePipeline
    from repro.hw.specs import TPU_V5E
    custom = dc.replace(TPU_V5E, name="tpu_custom")
    pipe = ForgePipeline(spec=custom)
    assert pipe.spec is custom
    assert "spec_name=tpu_custom" in pipe.policy_signature()


def test_report_stats_are_per_batch_delta():
    """A reused Forge accumulates lifetime counters on forge.stats, but each
    report's stats describe only its own batch."""
    forge = Forge(ForgeConfig())
    first = forge.optimize(_job("gemm_bias_gelu"))
    assert first.stats.cache_misses == 1 and first.stats.cache_hits == 0
    second = forge.optimize(_job("gemm_bias_gelu"))
    assert second.stats.cache_hits == 1 and second.stats.cache_misses == 0
    assert second.cache_hits == 1                  # per-result view agrees
    assert forge.stats.jobs == 2                   # lifetime counter


def test_facade_matches_direct_pipeline_result():
    """The facade is plumbing, not policy: same job, same outcome as the
    single-job ForgePipeline path."""
    from repro.core import ForgePipeline
    from repro.ir.fingerprint import program_canonical
    s = SPECS["gemm_swish_tanh_scale"]
    direct = ForgePipeline().optimize(
        s.name,
        build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
        build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
        tags=tuple(s.tags), target_dtype=s.target_dtype,
        rtol=s.rtol, atol=s.atol, meta=dict(s.meta))
    via_facade = Forge(ForgeConfig()).optimize(_job(s.name)).result.result
    assert program_canonical(via_facade.bench_program) \
        == program_canonical(direct.bench_program)
    assert via_facade.optimized_time == pytest.approx(direct.optimized_time)
