"""Transfer-aware result store: KB-content-hash key versioning, family
(near-miss) fingerprint transfer, LRU eviction, atomic + tolerant
persistence, and the baseline regression gate."""

import json
import pathlib
import shutil

import pytest

from repro.core import (ForgePipeline, KernelJob, OptimizationEngine,
                        ResultStore)
from repro.ir import GraphBuilder
from repro.ir.cost import graph_flops
from repro.ir.fingerprint import fingerprint_family
from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule
from repro.kb.loader import KnowledgeBase

KB_DATA = pathlib.Path(__file__).resolve().parents[1] / "src/repro/kb/data"


def _gemm(name, m, n, k):
    b = GraphBuilder(name)
    x = b.input((m, k), name="x")
    w = b.param((k, n), name="w")
    mm = b.matmul(x, w, name="mm")
    g = b.done(b.gelu(mm, name="act"))
    sched = eager_schedule(g)
    for grp in sched.groups:
        if grp.root == "mm":
            grp.impl = "pallas_naive"
            grp.config = PallasConfig(128, 128, 32, num_stages=1)
    return KernelProgram(name, g, sched, original_flops=graph_flops(g))


def _job(m, n, k, name="gemm"):
    """A gemm job: ci shapes scaled down, bench shapes as given."""
    return KernelJob(name,
                     _gemm(name, min(m, 256), min(n, 256), min(k, 128)),
                     _gemm(name, m, n, k), tags=("gemm",))


# ----------------------------------------------------------------------
# KB content hash
# ----------------------------------------------------------------------

def test_kb_content_hash_stable_across_reloads(tmp_path):
    root = tmp_path / "kb"
    shutil.copytree(KB_DATA, root)
    assert KnowledgeBase.load(root).content_hash() \
        == KnowledgeBase.load(root).content_hash()


def test_kb_edit_changes_content_hash(tmp_path):
    root = tmp_path / "kb"
    shutil.copytree(KB_DATA, root)
    before = KnowledgeBase.load(root).content_hash()
    # even a comment-only edit counts: the hash covers raw file bytes
    f = sorted(root.glob("*.yaml"))[0]
    f.write_text(f.read_text() + "\n# edited\n")
    assert KnowledgeBase.load(root).content_hash() != before


def test_kb_constructed_fallback_hash():
    a = KnowledgeBase([], [], [])
    b = KnowledgeBase([], [], [])
    assert a.content_hash() == b.content_hash()


def test_kb_edit_turns_exact_hit_into_miss(tmp_path):
    """Acceptance criterion: editing any KB YAML changes content_hash() and
    invalidates a previously-exact cache hit (no stale replay)."""
    root = tmp_path / "kb"
    shutil.copytree(KB_DATA, root)
    cache = tmp_path / "cache.json"

    eng1 = OptimizationEngine(ForgePipeline(kb=KnowledgeBase.load(root)),
                              cache_path=cache)
    r1 = eng1.submit(_job(2048, 2048, 512))
    assert not r1.cache_hit

    # control: unedited KB in a fresh engine replays from disk
    eng2 = OptimizationEngine(ForgePipeline(kb=KnowledgeBase.load(root)),
                              cache_path=cache)
    assert eng2.submit(_job(2048, 2048, 512)).cache_hit

    # edit the KB -> same job misses the exact index
    f = sorted(root.glob("*.yaml"))[0]
    f.write_text(f.read_text() + "\n# kb edited\n")
    eng3 = OptimizationEngine(ForgePipeline(kb=KnowledgeBase.load(root)),
                              cache_path=cache)
    r3 = eng3.submit(_job(2048, 2048, 512))
    assert not r3.cache_hit
    assert eng3.stats.cache_hits == 0
    assert eng3.stats.cache_misses == 1


# ----------------------------------------------------------------------
# Family (near-miss) transfer
# ----------------------------------------------------------------------

def test_family_fingerprint_collides_across_dims():
    a, b = _job(4096, 4096, 1024), _job(2048, 1024, 512)
    assert a.fingerprint("v5e") != b.fingerprint("v5e")
    assert a.family_fingerprint("v5e") == b.family_fingerprint("v5e")


def test_family_fingerprint_distinguishes_structure():
    b = GraphBuilder("p")
    x = b.input((256, 128), name="x")
    w = b.param((128, 256), name="w")
    mm = b.matmul(x, w, name="mm")
    g = b.done(b.relu(mm, name="act"))      # different activation op
    sched = eager_schedule(g)
    other = KernelProgram("p", g, sched, original_flops=graph_flops(g))
    gemm = _gemm("p", 256, 256, 128)
    assert fingerprint_family(gemm, gemm, "v5e", "bfloat16") \
        != fingerprint_family(other, other, "v5e", "bfloat16")


def test_family_transfer_warm_starts_and_matches_cold(tmp_path):
    """Acceptance criterion: a same-builder/different-dims job records a
    family transfer in EngineStats and completes with fewer stage-loop
    proposals than a cold run — while producing the identical result.

    Pinned to the legacy search knobs (counts priors, no cost ranking):
    under the learned-search defaults the *cold* run early-stops too, so
    the strict proposal-count gap is the legacy policy's property; the
    mined-policy gap is asserted by the pipeline-throughput search gate."""
    from repro.core import ForgeConfig

    def _legacy():
        return ForgePipeline(config=ForgeConfig(
            prior_policy="counts", cost_rank_proposals=False))

    eng = OptimizationEngine(_legacy(), workers=1)
    cold_a = eng.submit(_job(4096, 4096, 1024))
    assert not cold_a.cache_hit and not cold_a.transfer

    warm_b = eng.submit(_job(2048, 1024, 512))
    assert not warm_b.cache_hit
    assert warm_b.transfer and warm_b.seed_steps > 0
    assert eng.stats.family_transfers == 1
    assert eng.stats.transfer_fallbacks == 0

    cold_b = OptimizationEngine(_legacy(), workers=1).submit(
        _job(2048, 1024, 512))
    assert warm_b.result.proposals < cold_b.result.proposals
    assert warm_b.result.optimized_time \
        == pytest.approx(cold_b.result.optimized_time)
    # never-degrade holds on the transfer path
    assert warm_b.result.optimized_time <= warm_b.result.original_time


def test_partial_transfer_never_degrades():
    """A neighbor log that only partially applies (bogus tail) seeds the
    prefix, then the full search continues — same final result as cold."""
    eng = OptimizationEngine(workers=1)
    cold = eng.submit(_job(4096, 4096, 1024))
    entry = eng.cache.get(cold.fingerprint)
    assert entry and entry["transform_log"]
    entry["transform_log"] = entry["transform_log"] + [
        {"stage": "fusion", "pattern_id": "nonsense",
         "description": "does:not:exist"}]
    eng.cache.put(cold.fingerprint, entry, family=entry.get("family"))

    warm = eng.submit(_job(2048, 1024, 512))
    assert warm.transfer and warm.seed_steps > 0
    assert warm.result.optimized_time <= warm.result.original_time
    cold_b = OptimizationEngine(workers=1).submit(_job(2048, 1024, 512))
    assert warm.result.optimized_time \
        == pytest.approx(cold_b.result.optimized_time)


def test_useless_neighbor_counts_as_transfer_fallback():
    """A family neighbor whose log applies zero steps falls back to the
    full search and is counted as a transfer fallback, not a transfer."""
    eng = OptimizationEngine(workers=1)
    cold = eng.submit(_job(4096, 4096, 1024))
    entry = eng.cache.get(cold.fingerprint)
    entry["transform_log"] = [{"stage": "fusion", "pattern_id": "nonsense",
                               "description": "does:not:exist"}]
    eng.cache.put(cold.fingerprint, entry, family=entry.get("family"))

    warm = eng.submit(_job(2048, 1024, 512))
    assert not warm.transfer and warm.seed_steps == 0
    assert eng.stats.transfer_fallbacks == 1
    assert warm.result.optimized_time <= warm.result.original_time


def test_diverged_exact_entry_not_used_as_own_seed():
    """When an exact entry's replay diverges, the job must not be handed
    that same entry back as a family seed (replay fallback -> full run)."""
    eng = OptimizationEngine(workers=1)
    r1 = eng.submit(_job(4096, 4096, 1024))
    entry = eng.cache.get(r1.fingerprint)
    entry["transform_log"] = [{"stage": "fusion", "pattern_id": "nonsense",
                               "description": "does:not:exist"}]
    eng.cache.put(r1.fingerprint, entry, family=entry.get("family"))
    r2 = eng.submit(_job(4096, 4096, 1024))
    assert not r2.cache_hit and not r2.transfer
    assert eng.stats.replay_fallbacks == 1
    assert eng.stats.family_transfers == 0


# ----------------------------------------------------------------------
# Store mechanics: LRU eviction, versioning, atomic + tolerant persistence
# ----------------------------------------------------------------------

def test_lru_eviction_respects_cap():
    store = ResultStore(max_entries=2)
    store.put("a", {"transform_log": []}, family="famA")
    store.put("b", {"transform_log": []}, family="famA")
    store.put("c", {"transform_log": []}, family="famC")
    assert len(store) == 2
    assert store.get("a") is None          # oldest evicted
    assert store.get("b") is not None
    assert store.evictions == 1
    # family index follows eviction: famA still serves b, never a
    assert store.get_family("famA") is not None
    assert store.get_family("famA", exclude="b") is None


def test_reput_without_family_drops_stale_index():
    store = ResultStore()
    store.put("k", {"transform_log": []}, family="fam")
    store.put("k", {"transform_log": []})           # family dropped
    assert store.get_family("fam") is None
    store.put("k", {"transform_log": []}, family="fam2")  # family changed
    assert store.get_family("fam") is None
    assert store.get_family("fam2") is not None


def test_lru_get_refreshes_recency():
    store = ResultStore(max_entries=2)
    store.put("a", {"transform_log": []})
    store.put("b", {"transform_log": []})
    store.get("a")                          # refresh a -> b becomes LRU
    store.put("c", {"transform_log": []})
    assert store.get("a") is not None
    assert store.get("b") is None


def test_load_enforces_cap(tmp_path):
    path = tmp_path / "cache.json"
    big = ResultStore(path, max_entries=8)
    for i in range(8):
        big.put(f"k{i}", {"transform_log": []}, flush=False)
    big.flush()
    small = ResultStore(path, max_entries=3)
    assert len(small) == 3
    assert small.get("k0") is None and small.get("k7") is not None


def test_best_of_k_with_seed(tmp_path):
    """best_of_k > 1 on the transfer path: seed applies once up front and
    every pass still benefits (result matches the k=1 transfer run)."""
    eng1 = OptimizationEngine(workers=1)
    eng1.submit(_job(4096, 4096, 1024))
    k1 = eng1.submit(_job(2048, 1024, 512))
    assert k1.transfer

    engk = OptimizationEngine(ForgePipeline(best_of_k=2))
    engk.submit(_job(4096, 4096, 1024))
    kk = engk.submit(_job(2048, 1024, 512))
    assert kk.transfer and kk.seed_steps == k1.seed_steps
    assert kk.result.optimized_time \
        == pytest.approx(k1.result.optimized_time)


def test_corrupt_cache_file_discarded(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{ this is not json")
    store = ResultStore(path)
    assert len(store) == 0
    store.put("k", {"transform_log": []})   # still usable + flushable
    assert json.loads(path.read_text())["version"] == 2


def test_old_format_cache_discarded(tmp_path):
    path = tmp_path / "cache.json"
    # PR-1 v1 layout: no version field
    path.write_text(json.dumps({"entries": {"k": {"transform_log": []}}}))
    assert len(ResultStore(path)) == 0


def test_pre_facade_store_loads_and_transfers(tmp_path):
    """Acceptance gate for the ForgeConfig signature change: store files
    recorded *before* the typed-config PR (same on-disk version 2, but exact
    keys folded the old hand-built policy string) must still load tolerantly.
    Invalidation happens only through the exact-key miss caused by the new
    policy signature — family entries (not policy-keyed at the store layer)
    still serve transfer seeds, and nothing crashes or discards the file."""
    path = tmp_path / "cache.json"
    eng = OptimizationEngine(workers=1, cache_path=path)
    cold = eng.submit(_job(2048, 1024, 512))
    assert not cold.cache_hit
    data = json.loads(path.read_text())
    assert data["version"] == 2
    [(key, entry)] = data["entries"].items()
    # simulate the pre-PR file: same format, but the exact key was derived
    # from the old "T=5;k=1;..." policy string, so it cannot collide with
    # any key the new signature produces
    fam = entry["family"]
    old_key = "0" * len(key)
    path.write_text(json.dumps(
        {"version": 2, "entries": {old_key: entry}}))

    eng2 = OptimizationEngine(workers=1, cache_path=path)
    assert len(eng2.cache) == 1                      # loaded, not discarded
    assert eng2.cache.get(old_key) == entry
    res = eng2.submit(_job(2048, 1024, 512))
    # exact miss (policy signature changed) but the old entry's family index
    # still seeds the warm start — invalidation, not data loss
    assert not res.cache_hit
    assert res.transfer and res.seed_steps > 0
    assert eng2.cache.family_members(fam)


def test_pre_ladder_store_loads_and_transfers(tmp_path):
    """Acceptance gate for the family-ladder change: store files written
    *before* this PR (same on-disk version 2, but entries carry no
    ``family_ladder``/``dims`` fields and exact keys fold the pre-knob
    policy signature) must still load and serve transfer seeds through the
    coarsest (rank) tier — the ladder's rank key is byte-identical to the
    old family key by construction."""
    path = tmp_path / "cache.json"
    eng = OptimizationEngine(workers=1, cache_path=path)
    cold = eng.submit(_job(4096, 4096, 1024))
    assert not cold.cache_hit
    data = json.loads(path.read_text())
    [(key, entry)] = data["entries"].items()
    assert "family_ladder" in entry and "dims" in entry
    # simulate the pre-PR file: drop the ladder fields and rewrite the
    # exact key as the old policy signature would have produced it
    old_entry = {k: v for k, v in entry.items()
                 if k not in ("family_ladder", "dims")}
    fam = old_entry["family"]
    path.write_text(json.dumps(
        {"version": 2, "entries": {"0" * len(key): old_entry}}))

    eng2 = OptimizationEngine(workers=1, cache_path=path)
    assert len(eng2.cache) == 1
    res = eng2.submit(_job(2048, 1024, 512))
    assert not res.cache_hit
    assert res.transfer and res.seed_steps > 0
    assert eng2.cache.family_members(fam)
    assert res.result.optimized_time <= res.result.original_time


def test_fingerprint_keys_unchanged_by_api_redesign():
    """ir/fingerprint.py is the stable layer: the facade/config redesign
    must not drift the structural keys (family transfer across PRs depends
    on it)."""
    job = _job(2048, 1024, 512)
    fam = job.family_fingerprint("tpu_v5e", policy="")
    assert fam == fingerprint_family(job.ci_program, job.bench_program,
                                     "tpu_v5e", "bfloat16", ("gemm",),
                                     meta={}, policy="")
    # same builder at other dims -> same family key (rank abstraction)
    assert _job(4096, 2048, 1024).family_fingerprint("tpu_v5e") == fam


def test_atomic_write_and_family_roundtrip(tmp_path):
    path = tmp_path / "cache.json"
    store = ResultStore(path)
    store.put("k1", {"transform_log": [], "x": 1}, family="fam")
    assert not path.with_name(path.name + ".tmp").exists()
    data = json.loads(path.read_text())
    assert data["version"] == 2
    assert data["entries"]["k1"]["family"] == "fam"
    # reload rebuilds the family index from entries
    store2 = ResultStore(path)
    assert store2.get_family("fam")["x"] == 1


def test_same_family_batch_serial_concurrent_equivalence():
    """Transfer seeding must not make concurrent results racy: a batch of
    same-builder/different-dims jobs produces identical results (and
    identical transfer stats) under workers=1 and workers=3, thanks to
    two-phase scheduling with per-phase seed snapshots."""
    from repro.ir.fingerprint import program_canonical

    def jobs():
        return [_job(4096, 4096, 1024, name="a"),
                _job(2048, 1024, 512, name="b"),
                _job(1024, 2048, 512, name="c")]

    serial_eng = OptimizationEngine(workers=1)
    conc_eng = OptimizationEngine(workers=3)
    serial = serial_eng.run_batch(jobs())
    conc = conc_eng.run_batch(jobs())
    assert serial_eng.stats.as_dict() == conc_eng.stats.as_dict()
    assert serial_eng.stats.family_transfers == 2   # leader seeds b and c
    for a, b in zip(serial, conc):
        assert (a.cache_hit, a.transfer, a.seed_steps) \
            == (b.cache_hit, b.transfer, b.seed_steps)
        assert program_canonical(a.result.bench_program) \
            == program_canonical(b.result.bench_program)
        assert a.result.optimized_time == pytest.approx(b.result.optimized_time)


def test_engine_inflight_pruned_after_batch():
    eng = OptimizationEngine(workers=2)
    eng.run_batch([_job(2048, 2048, 512, name=f"j{i}") for i in range(2)])
    assert eng._inflight == {}


# ----------------------------------------------------------------------
# Baseline regression gate
# ----------------------------------------------------------------------

def test_diff_against_baseline():
    from benchmarks.run import diff_against_baseline
    base = {"kernels": [{"name": "a", "us_per_call": 100.0},
                        {"name": "b", "us_per_call": 100.0},
                        {"name": "c", "us_per_call": 100.0},
                        {"name": "gone", "us_per_call": 1.0}]}
    new = {"kernels": [{"name": "a", "us_per_call": 100.0},
                       {"name": "b", "us_per_call": 120.0},
                       {"name": "c", "us_per_call": 50.0},
                       {"name": "fresh", "us_per_call": 1.0}]}
    diff = diff_against_baseline(new, base, threshold=0.05)
    assert [r[0] for r in diff["regressions"]] == ["b"]
    assert [r[0] for r in diff["improvements"]] == ["c"]
    assert diff["new"] == ["fresh"]
    assert diff["removed"] == ["gone"]
    # within-threshold jitter is not a regression
    ok = {"kernels": [{"name": "a", "us_per_call": 104.0}]}
    assert diff_against_baseline(ok, base)["regressions"] == []
    # a 0us baseline entry cannot mask a real regression
    zero = {"kernels": [{"name": "z", "us_per_call": 0.0}]}
    slow = {"kernels": [{"name": "z", "us_per_call": 10.0}]}
    assert [r[0] for r in diff_against_baseline(slow, zero)["regressions"]] \
        == ["z"]
