"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.epilogue import EpilogueOp
from repro.kernels.decode_attention import decode_attention
from repro.kernels.elementwise import elementwise_chain
from repro.kernels.flash_attention import attention_unoptimized, flash_attention
from repro.kernels.matmul_fused import matmul_fused, matmul_fused_naive
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan


def _arr(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


EPI = [EpilogueOp("bias_add", operand="bias"), EpilogueOp("gelu"),
       EpilogueOp("scale", value=0.5)]


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 384, 192),
                                   (256, 300, 192), (200, 256, 160)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_fused_shapes_dtypes(rng, m, n, k, dtype):
    a, b = _arr(rng, m, k, dtype=dtype), _arr(rng, k, n, dtype=dtype)
    bias = _arr(rng, n, dtype=dtype)
    want = ref.matmul_fused_ref(a, b, EPI, {"bias": bias})
    got = matmul_fused(a, b, block_m=128, block_n=128, block_k=64,
                       epilogue=EPI, operands={"bias": bias},
                       out_dtype=jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("reduction", ["sum", "max", "min", "mean"])
def test_matmul_reduction_epilogue(rng, reduction):
    a, b = _arr(rng, 256, 192), _arr(rng, 192, 320)
    want = ref.matmul_fused_ref(a, b, [EpilogueOp("gelu")], {},
                                reduction=reduction)
    got = matmul_fused(a, b, block_m=128, block_n=128, block_k=64,
                       epilogue=[EpilogueOp("gelu")], reduction=reduction)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_matmul_swizzle_equivalence(rng):
    """GROUP_M traversal must not change results."""
    a, b = _arr(rng, 512, 256), _arr(rng, 256, 512)
    base = matmul_fused(a, b, block_m=128, block_n=128, block_k=128, group_m=1)
    for gm in (2, 4, 8):
        got = matmul_fused(a, b, block_m=128, block_n=128, block_k=128,
                           group_m=gm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-6)


def test_matmul_naive_requires_divisible(rng):
    a, b = _arr(rng, 200, 128), _arr(rng, 128, 256)
    with pytest.raises(ValueError, match="boundary"):
        matmul_fused_naive(a, b, block_m=128, block_n=128, block_k=64)


@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([64, 128, 256]), skv=st.sampled_from([128, 256]),
       h=st.sampled_from([4, 8]), hkv=st.sampled_from([1, 2, 4]),
       causal=st.booleans(), seed=st.integers(0, 99))
def test_flash_attention_property(sq, skv, h, hkv, causal, seed):
    if h % hkv:
        return
    if causal and sq > skv:
        return  # queries preceding the KV window are fully masked (NaN ref)
    rng = np.random.default_rng(seed)
    d = 64
    q = _arr(rng, 2, h, sq, d)
    k = _arr(rng, 2, hkv, skv, d)
    v = _arr(rng, 2, hkv, skv, d)
    kk = jnp.repeat(k, h // hkv, axis=1)
    vv = jnp.repeat(v, h // hkv, axis=1)
    want = ref.attention_ref(q, kk, vv, causal=causal)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_windowed(rng):
    q = _arr(rng, 1, 4, 256, 64)
    k = _arr(rng, 1, 4, 256, 64)
    v = _arr(rng, 1, 4, 256, 64)
    want = ref.attention_ref(q, k, v, causal=True, window=64)
    got = flash_attention(q, k, v, causal=True, window=64,
                          block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_unoptimized_matches_flash(rng):
    """The 'original' kernel and the optimized kernel agree (the paper's
    correctness-across-the-before/after-pair requirement)."""
    q = _arr(rng, 2, 4, 128, 64)
    k = _arr(rng, 2, 2, 128, 64)
    v = _arr(rng, 2, 2, 128, 64)
    a = attention_unoptimized(q, k, v, causal=True)
    b = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_ragged_lengths(rng):
    q = _arr(rng, 4, 8, 64)
    k = _arr(rng, 4, 2, 512, 64)
    v = _arr(rng, 4, 2, 512, 64)
    lengths = jnp.array([512, 300, 17, 1], jnp.int32)
    kk, vv = jnp.repeat(k, 4, axis=1), jnp.repeat(v, 4, axis=1)
    want = ref.decode_attention_ref(q, kk, vv, lengths=lengths)
    got = decode_attention(q, k, v, lengths=lengths, block_kv=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rows,d", [(64, 128), (512, 384), (100, 256)])
def test_rmsnorm_sweep(rng, rows, d):
    x, w = _arr(rng, rows, d), _arr(rng, d)
    np.testing.assert_allclose(np.asarray(rmsnorm(x, w, block_rows=64)),
                               np.asarray(ref.rmsnorm_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_elementwise_chain_with_operands(rng):
    x = _arr(rng, 256, 192)
    r = _arr(rng, 256, 192)
    epi = [EpilogueOp("mul", operand="r"), EpilogueOp("tanh"),
           EpilogueOp("clamp_min", value=-0.5)]
    got = elementwise_chain(x, epi, operands={"r": r}, block_rows=64)
    want = ref.elementwise_chain_ref(x, epi, {"r": r})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(l=st.sampled_from([64, 128, 256]), chunk=st.sampled_from([32, 64]),
       seed=st.integers(0, 20))
def test_ssd_scan_property(l, chunk, seed):
    rng = np.random.default_rng(seed)
    B, H, P, N = 2, 2, 16, 32
    x = _arr(rng, B, l, H, P)
    dt = jnp.abs(_arr(rng, B, l, H)) * 0.1 + 0.01
    a = -jnp.abs(_arr(rng, H)) - 0.1
    bm = _arr(rng, B, l, N) * 0.3
    cm = _arr(rng, B, l, N) * 0.3
    want_y, want_s = ref.ssd_ref(x, dt, a, bm, cm)
    from repro.kernels.ops import ssd
    got_y, got_s = ssd(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=5e-4, atol=5e-4)
