"""Fleet engine: cache hit/replay correctness, serial-vs-concurrent
equivalence, persistence, warm-start priors, transform-log replay."""

import json

import pytest

from repro.aibench import build_program, load_specs
from repro.core import (ForgePipeline, KernelJob, OptimizationEngine,
                        ResultCache, TransformLog)
from repro.core.history import History
from repro.core.stage_scheduler import WarmStartProposer
from repro.core.proposers import BaseProposer, Candidate
from repro.ir.fingerprint import program_canonical

SPECS = {s.name: s for s in load_specs()}


def _job(name):
    s = SPECS[name]
    return KernelJob(s.name,
                     build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
                     build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
                     tags=tuple(s.tags), target_dtype=s.target_dtype,
                     rtol=s.rtol, atol=s.atol, meta=dict(s.meta))


NAMES = ["gemm_bias_gelu", "gemm_swish_tanh_scale", "matmul_t_gelu"]


def test_cache_hit_replays_bit_identical():
    eng = OptimizationEngine(workers=1)
    first = eng.run_batch([_job(n) for n in NAMES])
    assert all(not r.cache_hit for r in first)
    second = eng.run_batch([_job(n) for n in NAMES])
    assert all(r.cache_hit for r in second)
    assert eng.stats.cache_hits == len(NAMES)
    assert eng.stats.cache_misses == len(NAMES)
    for a, b in zip(first, second):
        assert program_canonical(a.result.bench_program) \
            == program_canonical(b.result.bench_program)
        assert a.result.optimized_time == pytest.approx(b.result.optimized_time)


def test_replay_is_faster_than_search():
    """Replay verifies once per accepted transform, so the transform log is
    never longer than the cold run's total iteration count."""
    eng = OptimizationEngine(workers=1)
    cold = eng.submit(_job("gemm_bias_gelu"))
    warm = eng.submit(_job("gemm_bias_gelu"))
    assert warm.cache_hit
    cold_iters = sum(r.iterations for r in cold.result.stage_records)
    warm_iters = sum(r.iterations for r in warm.result.stage_records)
    assert warm_iters <= cold_iters
    assert len(warm.result.stage_records) == len(cold.result.transform_log)


def test_serial_concurrent_equivalence():
    jobs = lambda: [_job(n) for n in NAMES]
    serial = OptimizationEngine(workers=1).run_batch(jobs())
    conc = OptimizationEngine(workers=3).run_batch(jobs())
    assert [r.job.name for r in serial] == [r.job.name for r in conc]
    for a, b in zip(serial, conc):
        assert program_canonical(a.result.bench_program) \
            == program_canonical(b.result.bench_program)
        assert a.result.optimized_time == pytest.approx(b.result.optimized_time)


def test_structural_twins_share_cache_entry():
    """Two jobs that build the same structure under different names hit the
    same cache entry — the second replays."""
    eng = OptimizationEngine(workers=1)
    a = _job("gemm_bias_gelu")
    b = _job("gemm_bias_gelu")
    b.name = "gemm_bias_gelu_twin"
    ra = eng.submit(a)
    rb = eng.submit(b)
    assert ra.fingerprint == rb.fingerprint
    assert not ra.cache_hit and rb.cache_hit


def test_tolerances_split_cache_entries():
    eng = OptimizationEngine(workers=1)
    a = _job("gemm_bias_gelu")
    b = _job("gemm_bias_gelu")
    b.rtol = b.rtol * 10
    assert eng.submit(a).fingerprint != eng.submit(b).fingerprint


def test_meta_splits_cache_entries():
    """meta drives the analyzer (host_sync etc.), so it must key the cache."""
    a = _job("gemm_bias_gelu")
    b = _job("gemm_bias_gelu")
    b.meta = {"host_sync": True}
    assert a.fingerprint("v5e") != b.fingerprint("v5e")


def test_pipeline_policy_splits_cache_entries():
    """A stage-ablated pipeline must not replay full-pipeline results."""
    from repro.core import ForgePipeline
    full = OptimizationEngine(ForgePipeline())
    ablated = OptimizationEngine(ForgePipeline(stages_enabled=["fusion"]))
    job = _job("gemm_bias_gelu")
    fp_full = job.fingerprint(full.pipeline.spec.name,
                              full.pipeline.policy_signature())
    fp_abl = job.fingerprint(ablated.pipeline.spec.name,
                             ablated.pipeline.policy_signature())
    assert fp_full != fp_abl


def test_renamed_twin_replays_via_canonical_descriptions():
    """A structural twin under different node names must actually replay
    (canonical-description matching), not fall back to a full run."""
    from repro.ir import GraphBuilder
    from repro.ir.cost import graph_flops
    from repro.ir.schedule import KernelProgram, PallasConfig, eager_schedule

    def build(m, n, k, names):
        b = GraphBuilder("p")
        x = b.input((m, k), name=names[0])
        w = b.param((k, n), name=names[1])
        mm = b.matmul(x, w, name=names[2])
        g = b.done(b.gelu(mm, name=names[3]))
        sched = eager_schedule(g)
        for grp in sched.groups:
            if grp.root == names[2]:
                grp.impl = "pallas_naive"
                grp.config = PallasConfig(128, 128, 32, num_stages=1)
        return KernelProgram("p", g, sched, original_flops=graph_flops(g))

    def job(names):
        return KernelJob("twin", build(256, 256, 128, names),
                         build(4096, 4096, 1024, names), tags=("gemm",))

    eng = OptimizationEngine(workers=1)
    a = eng.submit(job(("x", "w", "mm", "act")))
    b = eng.submit(job(("inp", "weights", "prod", "activation")))
    assert a.fingerprint == b.fingerprint
    assert b.cache_hit, "renamed twin must replay, not fall back"
    assert eng.stats.replay_fallbacks == 0
    assert program_canonical(a.result.bench_program)["schedule"] \
        == program_canonical(b.result.bench_program)["schedule"]


def test_inflight_dedup_coalesces_duplicate_jobs():
    """N identical jobs in one concurrent batch do 1 full run + N-1 replays,
    not N full searches."""
    eng = OptimizationEngine(workers=4)
    results = eng.run_batch([_job("gemm_bias_gelu") for _ in range(4)])
    assert sum(1 for r in results if not r.cache_hit) == 1
    assert sum(1 for r in results if r.cache_hit) == 3
    assert eng.stats.cache_misses == 1 and eng.stats.cache_hits == 3


def test_cache_persistence_roundtrip(tmp_path):
    path = tmp_path / "cache.json"
    eng1 = OptimizationEngine(workers=1, cache_path=path)
    r1 = eng1.submit(_job("gemm_bias_gelu"))
    assert path.exists()
    entry = json.loads(path.read_text())["entries"][r1.fingerprint]
    assert entry["transform_log"], "winning sequence must be recorded"
    # a fresh engine (fresh process analogue) replays from disk
    eng2 = OptimizationEngine(workers=1, cache_path=path)
    r2 = eng2.submit(_job("gemm_bias_gelu"))
    assert r2.cache_hit
    assert program_canonical(r2.result.bench_program)["schedule"] \
        == entry["canonical_schedule"]


def test_transform_log_serializable():
    eng = OptimizationEngine(workers=1)
    res = eng.submit(_job("gemm_bias_gelu")).result
    log = res.transform_log
    assert len(log) > 0
    rt = TransformLog.from_list(log.to_list())
    assert rt.to_list() == log.to_list()
    for step in log:
        assert step.stage and step.description


def test_history_warm_start_reorders_candidates():
    class TwoPatternProposer(BaseProposer):
        stage = "gpu_specific"

        def candidates(self, program, issues, trajectory):
            yield Candidate("a", "cand_a", lambda p: p.copy(), "pat_a")
            yield Candidate("b", "cand_b", lambda p: p.copy(), "pat_b")

    hist = History()
    for _ in range(3):
        hist.record("p", "gpu_specific", "pat_b", True, 2.0, 1)
    warm = WarmStartProposer(TwoPatternProposer(None, None),
                             hist.snapshot_priors())
    ordered = [c.pattern_id for c in warm.candidates(None, [], [])]
    assert ordered == ["pat_b", "pat_a"]
    # empty priors: transparent pass-through
    cold = WarmStartProposer(TwoPatternProposer(None, None), {})
    assert [c.pattern_id for c in cold.candidates(None, [], [])] \
        == ["pat_a", "pat_b"]


def test_history_thread_safe_merge():
    h1 = History()
    h2 = History()
    h2.record("p", "fusion", "fuse_epilogue_into_matmul", True, 2.0, 1)
    h1.merge(h2)
    assert h1.priority("fuse_epilogue_into_matmul") == 1


def test_replay_fallback_on_corrupt_entry():
    """A cache entry whose log can't be matched falls back to a full run
    (correctness over cache)."""
    eng = OptimizationEngine(workers=1)
    r1 = eng.submit(_job("gemm_bias_gelu"))
    entry = eng.cache.get(r1.fingerprint)
    entry["transform_log"] = [{"stage": "fusion", "pattern_id": "nonsense",
                               "description": "does:not:exist"}]
    eng.cache.put(r1.fingerprint, entry)
    r2 = eng.submit(_job("gemm_bias_gelu"))
    assert not r2.cache_hit
    assert eng.stats.replay_fallbacks >= 1
    # the fallback run rewrote the entry; next submission replays again
    r3 = eng.submit(_job("gemm_bias_gelu"))
    assert r3.cache_hit


def test_pipeline_single_job_wrapper_unchanged():
    """ForgePipeline.optimize stays the thin single-job path and now carries
    the transform log."""
    s = SPECS["gemm_bias_gelu"]
    pipe = ForgePipeline()
    res = pipe.optimize(
        s.name,
        build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
        build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
        tags=tuple(s.tags), target_dtype=s.target_dtype,
        rtol=s.rtol, atol=s.atol, meta=s.meta)
    assert res.speedup > 1
    assert res.transform_log is not None and len(res.transform_log) > 0
    improved_stages = [r.stage for r in res.stage_records if r.improved]
    assert [t.stage for t in res.transform_log] == improved_stages


def test_result_cache_clear(tmp_path):
    path = tmp_path / "c.json"
    cache = ResultCache(path)
    cache.put("k", {"transform_log": []})
    assert len(cache) == 1 and path.exists()
    cache.clear()
    assert len(cache) == 0 and not path.exists()
