"""Hardware query system + cost model: structural properties the optimizer
relies on (hypothesis-driven where shapes vary)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.hw.query import HardwareQuery
from repro.hw.specs import TPU_V5E, dtype_itemsize, get_spec
from repro.ir import GraphBuilder
from repro.ir.cost import CostModel, graph_flops
from repro.ir.schedule import (FusionGroup, KernelProgram, PallasConfig,
                               Schedule, eager_schedule)

HW = HardwareQuery(TPU_V5E)
CM = CostModel(TPU_V5E)


@settings(max_examples=40, deadline=None)
@given(m=st.integers(8, 16384), n=st.integers(128, 16384),
       k=st.integers(128, 16384),
       dtype=st.sampled_from(["bfloat16", "float32"]))
def test_optimal_params_always_valid(m, n, k, dtype):
    p = HW.get_optimal_params(m, n, k, dtype)
    sub, lane = TPU_V5E.min_tile(dtype)
    assert p.block_m >= 1 and p.block_n >= 1 and p.block_k >= 1
    assert p.block_m % sub == 0 or p.block_m >= m  # clamped tiny dims allowed
    assert p.block_n % lane == 0 or p.block_n >= n
    # VMEM budget always holds
    assert p.working_set_bytes(dtype_itemsize(dtype)) <= TPU_V5E.vmem_bytes
    # swizzle guard: never swizzle a single M-tile
    if -(-m // p.block_m) <= 1:
        assert p.group_m == 1


def test_skinny_matrices_get_asymmetric_tiles():
    tall = HW.get_optimal_params(65536, 512, 1024, "bfloat16")
    wide = HW.get_optimal_params(512, 65536, 1024, "bfloat16")
    assert tall.block_m >= tall.block_n
    assert wide.block_n >= wide.block_m


def test_autotune_grid_valid_and_bounded():
    grid = HW.autotune_grid(4096, 4096, 4096, "bfloat16")
    assert 1 <= len(grid) <= 12
    for p in grid:
        assert p.working_set_bytes(2) <= TPU_V5E.vmem_bytes


def _program(dtype="float32", impl="pallas_blockspec", cfg=None,
             m=2048, n=2048, k=2048):
    b = GraphBuilder("p", dtype=dtype)
    x = b.input((m, k), name="x")
    w = b.param((k, n), name="w")
    mm = b.matmul(x, w, name="mm")
    g = b.done(b.gelu(mm, name="act"))
    sched = eager_schedule(g)
    for grp in sched.groups:
        if grp.root == "mm":
            grp.impl = impl
            grp.config = cfg or PallasConfig(512, 512, 512, num_stages=2)
    return KernelProgram("p", g, sched, original_flops=graph_flops(g))


def test_bf16_faster_than_f32():
    p32 = _program()
    pbf = _program()
    pbf.schedule.compute_dtype = "bfloat16"
    assert CM.program_time(pbf) < CM.program_time(p32)


def test_f64_much_slower():
    assert CM.program_time(_program("float64")) > 2 * CM.program_time(_program())


def test_blockspec_beats_naive():
    naive = _program(impl="pallas_naive",
                     cfg=PallasConfig(128, 128, 32, num_stages=1))
    modern = _program()
    assert CM.program_time(modern) < CM.program_time(naive)


def test_fusion_reduces_time():
    p = _program()
    fused = _program()
    g = fused.schedule.groups
    mm_grp = next(x for x in g if x.root == "mm")
    act_grp = next(x for x in g if x.root == "act")
    mm_grp.nodes.append("act")
    fused.schedule.groups.remove(act_grp)
    assert CM.program_time(fused) < CM.program_time(p)


def test_persistent_removes_spills():
    base = _program(cfg=PallasConfig(512, 512, 256, num_stages=2,
                                     persistent=False), k=8192)
    pers = _program(cfg=PallasConfig(512, 512, 256, num_stages=2,
                                     persistent=True), k=8192)
    cb = CM.program_cost(base)
    cp = CM.program_cost(pers)
    assert cp.hbm_bytes < cb.hbm_bytes


def test_swizzle_reduces_traffic():
    no = _program(cfg=PallasConfig(256, 256, 2048, group_m=1), m=8192, n=8192)
    sw = _program(cfg=PallasConfig(256, 256, 2048, group_m=8), m=8192, n=8192)
    assert CM.program_cost(sw).hbm_bytes < CM.program_cost(no).hbm_bytes


def test_xla_reduction_epilogue_materializes():
    """XLA cannot elide the GEMM product across a reduction epilogue; a
    pallas group can (the paper's fusion-mode distinction)."""
    def build(impl):
        b = GraphBuilder("p")
        x = b.input((4096, 512), name="x")
        w = b.param((512, 8192), name="w")
        mm = b.matmul(x, w, name="mm")
        g = b.done(b.reduce_max(mm, axes=(1,), name="red"))
        sched = Schedule(groups=[FusionGroup("g0", ["mm", "red"], "mm", impl,
                                             PallasConfig(512, 512, 512))])
        return KernelProgram("p", g, sched, original_flops=graph_flops(g))
    assert (CM.program_cost(build("pallas_blockspec")).hbm_bytes
            < CM.program_cost(build("xla")).hbm_bytes)


def test_specs_table():
    assert get_spec("v5e").peak_flops_bf16 == pytest.approx(197e12)
    assert get_spec("tpu_v5e").hbm_bw == pytest.approx(819e9)
    with pytest.raises(KeyError):
        get_spec("h100")
