"""ForgeConfig: derived policy signatures (single-field sensitivity,
operational-field insensitivity, cross-process stability), the pickle/dict
codec, and the compatibility shims that fold old kwargs into a config."""

import dataclasses
import pickle
import subprocess
import sys

import pytest

from repro.core.config import ForgeConfig
from repro.core.pipeline import ForgePipeline

# one alternative value per field, different from the default
ALT_VALUES = {
    "spec_name": "tpu_v4",
    "max_iterations": 7,
    "best_of_k": 3,
    "use_pallas_exec": False,
    "use_planner": False,
    "warm_start": False,
    "stages_enabled": ("fusion", "autotuning"),
    "use_llm": True,
    "prior_policy": "counts",
    "cost_rank_proposals": False,
    "workers": 4,
    "execution_backend": "process",
    "cache_path": "/tmp/store.json",
    "cache_max_entries": 16,
    "dump_dir": "/tmp/dumps",
    "verify_fastpath": "check",
    "shared_verify_cache_bytes": 0,
    "batch_exec_planning": False,
    "fleet_address": "127.0.0.1:9444",
    "fleet_spawn_workers": 2,
    "fleet_connect_timeout_s": 30.0,
    "fleet_heartbeat_s": 1.0,
    "fleet_heartbeat_timeout_s": 5.0,
    "fleet_max_respawns": 1,
    "fleet_journal_path": "/tmp/fleet.wal",
    "fault_spec": '{"kill_worker_after_jobs":1}',
}


def test_every_field_has_an_alt_value():
    """ALT_VALUES must track the dataclass: a new field without an entry
    here would silently shrink the property tests below."""
    assert set(ALT_VALUES) == {f.name for f in dataclasses.fields(ForgeConfig)}


def test_single_policy_field_changes_signature():
    """Any two configs differing in any single policy field must produce
    different signatures — the auto-derivation guarantee that replaced the
    hand-maintained string (a forgotten knob can't poison the cache)."""
    base = ForgeConfig()
    for f in ForgeConfig.policy_fields():
        changed = base.replace(**{f.name: ALT_VALUES[f.name]})
        assert changed.policy_signature() != base.policy_signature(), f.name


def test_operational_fields_do_not_change_signature():
    """workers/cache location/dump dir cannot change what the pipeline
    produces (workers=1 and workers=N are result-equivalent by design), so
    they must NOT invalidate cached results."""
    base = ForgeConfig()
    assert {f.name for f in ForgeConfig.operational_fields()} == {
        "workers", "execution_backend", "cache_path", "cache_max_entries",
        "dump_dir", "verify_fastpath", "shared_verify_cache_bytes",
        "batch_exec_planning", "fleet_address", "fleet_spawn_workers",
        "fleet_connect_timeout_s", "fleet_heartbeat_s",
        "fleet_heartbeat_timeout_s", "fleet_max_respawns",
        "fleet_journal_path", "fault_spec"}
    for f in ForgeConfig.operational_fields():
        changed = base.replace(**{f.name: ALT_VALUES[f.name]})
        assert changed.policy_signature() == base.policy_signature(), f.name


def test_signature_property_sampled_pairs():
    """Property-style (hypothesis stub-compatible): random single-field
    perturbations over the policy domain always change the signature, and
    equal configs always agree."""
    from hypothesis import given, settings, strategies as st

    policy_names = [f.name for f in ForgeConfig.policy_fields()]

    @settings(max_examples=25)
    @given(idx=st.integers(min_value=0, max_value=len(policy_names) - 1))
    def prop(idx):
        name = policy_names[idx]
        base = ForgeConfig()
        changed = base.replace(**{name: ALT_VALUES[name]})
        assert changed.policy_signature() != base.policy_signature()
        assert base.policy_signature() == ForgeConfig().policy_signature()

    prop()


def test_signature_stable_across_pickle_roundtrip():
    cfg = ForgeConfig(max_iterations=3, stages_enabled=("fusion",),
                      workers=2)
    clone = pickle.loads(pickle.dumps(cfg))
    assert clone == cfg
    assert clone.policy_signature() == cfg.policy_signature()


def test_signature_stable_across_processes():
    """The signature is the cache key prefix shared by process-pool workers:
    a fresh interpreter must derive the identical string (no id()/hash()
    randomization leakage)."""
    cfg = ForgeConfig(best_of_k=2, stages_enabled=("fusion", "autotuning"))
    code = ("import sys, pickle; "
            "sys.stdout.write(pickle.loads(sys.stdin.buffer.read())"
            ".policy_signature())")
    out = subprocess.run([sys.executable, "-c", code],
                         input=pickle.dumps(cfg), capture_output=True,
                         env={"PYTHONPATH": "src"}, cwd=".",
                         check=True).stdout.decode()
    assert out == cfg.policy_signature()


def test_dict_codec_roundtrip():
    cfg = ForgeConfig(max_iterations=2, use_planner=False,
                      stages_enabled=("fusion",))
    d = cfg.to_dict()
    clone = ForgeConfig.from_dict(d)
    assert clone == cfg
    with pytest.raises(ValueError, match="unknown ForgeConfig fields"):
        ForgeConfig.from_dict({"no_such_knob": 1})


def test_validation():
    with pytest.raises(ValueError):
        ForgeConfig(max_iterations=0)
    with pytest.raises(ValueError):
        ForgeConfig(best_of_k=0)
    with pytest.raises(ValueError):
        ForgeConfig(workers=0)
    with pytest.raises(ValueError, match="unknown stage"):
        ForgeConfig(stages_enabled=("not_a_stage",))
    # lists normalize to tuples (hashable, picklable)
    assert ForgeConfig(stages_enabled=["fusion"]).stages_enabled == ("fusion",)


# ---------------------------------------------------------------------------
# compatibility shims
# ---------------------------------------------------------------------------

def test_pipeline_kwargs_fold_into_config():
    pipe = ForgePipeline(max_iterations=3, best_of_k=2, use_planner=False,
                         stages_enabled=["fusion", "gpu_specific"])
    assert pipe.config == ForgeConfig(
        max_iterations=3, best_of_k=2, use_planner=False,
        stages_enabled=("fusion", "gpu_specific"))
    assert pipe.T == 3 and pipe.k == 2 and not pipe.use_planner
    assert pipe.policy_signature() == pipe.config.policy_signature()


def test_pipeline_from_config_equals_kwarg_shim():
    a = ForgePipeline(max_iterations=4)
    b = ForgePipeline.from_config(ForgeConfig(max_iterations=4))
    assert a.policy_signature() == b.policy_signature()


def test_llm_presence_reaches_signature():
    class FakeLLM:
        def complete(self, *a, **k):
            return ""

    with_llm = ForgePipeline(llm=FakeLLM())
    without = ForgePipeline()
    assert with_llm.policy_signature() != without.policy_signature()
    # config= path must reflect the llm too
    shim = ForgePipeline(llm=FakeLLM(), config=ForgeConfig())
    assert shim.policy_signature() == with_llm.policy_signature()
