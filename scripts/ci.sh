#!/usr/bin/env bash
# CI pipeline, run as a sequence of named gates. Each gate is timed; the run
# stops at the first failure and always ends with a per-gate timing summary
# plus a single machine-greppable trailer line:
#   "CI OK"                      — every gate passed
#   "CI FAILED at gate: <name>"  — the first gate that failed
#
# Gates:
#   compile              byte-compile everything (catches syntax errors
#                        before pytest even collects — the seed shipped one)
#   ruff-lint            ruff over src/tests/benchmarks/scripts (skipped when
#                        ruff isn't installed — the dev container doesn't
#                        ship it; hosted CI does)
#   stage-registry       the stage DAG must validate; every stage needs a
#                        proposer factory and >=1 issue binding
#   tier1-tests          the full pytest suite; with pytest-cov installed
#                        (hosted CI) it also enforces >=60% line coverage
#                        over repro.core
#   forge-service        loopback Forge service e2e: submit two kernels via
#                        ForgeClient (one duplicate), assert completion,
#                        dedup, SSE stage events, and a graceful drain
#   backend-equivalence  serial / thread / process engines must produce
#                        identical per-kernel TransformLogs and speedups
#   remote-equivalence   the same harness over a 2-worker loopback
#                        distributed fleet: serial == remote, byte for byte
#   chaos                seeded fault injection (worker kill + respawn,
#                        coordinator crash + journal recovery, service
#                        restart mid-queue) must leave every report
#                        byte-equivalent to the undisturbed baseline
#   pipeline-throughput  the verification fast path must keep a >=1.5x
#                        end-to-end speedup over the uncached cascade with
#                        bit-identical results, and cross-job sharing must
#                        keep a >=1.4x marginal improvement on a shared-
#                        family batch (writes BENCH_pipeline.json)
#   warm-store           (opt-in: CI_BUILD_WARM_STORE=1) build the pre-seeded
#                        L2 ResultStore if the restored cache missed
#   l2-regression        when a previous BENCH_l2.json exists, re-run the l2
#                        suite — warm-started from results/warm_store.json
#                        when present — and fail on >5% per-kernel regressions
#
# The per-gate timing summary is also written to results/ci_gate_timings.json
# (hosted CI uploads it as an artifact to track gate-cost drift).
set -uo pipefail
cd "$(dirname "$0")/.."

WARM_STORE="${CI_WARM_STORE_PATH:-results/warm_store.json}"
TIMINGS_JSON="${CI_GATE_TIMINGS_PATH:-results/ci_gate_timings.json}"

GATE_NAMES=()
GATE_TIMES=()
FAILED_GATE=""

run_gate() {
  local name="$1"; shift
  echo ""
  echo "== gate: $name =="
  local t0=$SECONDS
  "$@"
  local status=$?
  GATE_NAMES+=("$name")
  GATE_TIMES+=($((SECONDS - t0)))
  if [ $status -ne 0 ]; then
    FAILED_GATE="$name"
  fi
  return $status
}

skip_gate() {
  # record a 0s entry so the summary shows what was skipped and why
  GATE_NAMES+=("$1 (skipped: $2)")
  GATE_TIMES+=(0)
}

write_timings_json() {
  # machine-readable gate timings (CI artifact — tracks gate-cost drift)
  mkdir -p "$(dirname "$TIMINGS_JSON")"
  {
    echo '{'
    echo '  "gates": ['
    local i last=$((${#GATE_NAMES[@]} - 1))
    for i in "${!GATE_NAMES[@]}"; do
      printf '    {"name": "%s", "seconds": %s}%s\n' \
        "${GATE_NAMES[$i]}" "${GATE_TIMES[$i]}" \
        "$([ "$i" -lt "$last" ] && echo ',')"
    done
    echo '  ],'
    printf '  "failed_gate": "%s"\n' "$FAILED_GATE"
    echo '}'
  } > "$TIMINGS_JSON"
}

summary() {
  local rc=$?
  echo ""
  echo "== gate timing summary =="
  local i
  for i in "${!GATE_NAMES[@]}"; do
    printf '  %-42s %5ss\n' "${GATE_NAMES[$i]}" "${GATE_TIMES[$i]}"
  done
  if [ ${#GATE_NAMES[@]} -gt 0 ]; then
    write_timings_json
  fi
  if [ -n "$FAILED_GATE" ]; then
    echo "CI FAILED at gate: $FAILED_GATE"
    exit 1
  fi
  if [ $rc -ne 0 ]; then
    # aborted outside any gate (set -u violation, signal, ...): never let
    # the trap launder a non-gate failure into "CI OK"
    echo "CI FAILED outside gates (exit $rc)"
    exit "$rc"
  fi
  echo "CI OK"
  exit 0
}
trap summary EXIT

run_gate compile \
  python -m compileall -q src tests benchmarks examples scripts || exit

# Lint gate (ROADMAP follow-up): config lives in pyproject.toml. The dev
# container doesn't ship ruff, so local runs skip rather than fail; hosted
# CI installs it and the gate is real there.
if command -v ruff > /dev/null 2>&1; then
  run_gate ruff-lint \
    ruff check src tests benchmarks examples scripts || exit
else
  skip_gate ruff-lint "ruff not installed"
fi

# (-W: silence runpy's already-imported RuntimeWarning.)
run_gate stage-registry \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -W ignore::RuntimeWarning -m repro.core.stages --check || exit

# Coverage gate rides the tier-1 run: hosted CI installs pytest-cov and the
# suite must keep >=60% line coverage over repro.core (the engine/verify
# hot core — a floor to ratchet, not a target); the dev container doesn't
# ship the plugin, so local runs measure nothing rather than fail.
COV_ARGS=()
if python -c "import pytest_cov" > /dev/null 2>&1; then
  COV_ARGS=(--cov=repro.core --cov-report=term --cov-fail-under=60)
else
  echo "pytest-cov not installed; tier1 runs without the coverage gate"
fi
run_gate tier1-tests \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
  ${COV_ARGS[@]+"${COV_ARGS[@]}"} "$@" \
  || exit

# Hosted-service gate: start the Forge service on loopback, drive it via
# ForgeClient — two submits (one an exact duplicate), assert completion,
# dedup (one engine execution, byte-identical reports), a nonzero SSE
# stage-event stream matching the report, and a graceful drain.
run_gate forge-service \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python scripts/forge_service_gate.py || exit

run_gate backend-equivalence \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python scripts/backend_equivalence.py --workers 2 || exit

# Distributed-fleet gate: the same equivalence harness against a loopback
# 2-worker fleet (coordinator on an ephemeral port, forge-worker processes
# handshaking over the versioned wire protocol) — serial == remote on
# both the cold and warm-prior rounds, byte for byte.
run_gate remote-equivalence \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python scripts/backend_equivalence.py --workers 2 \
    --backends serial,remote || exit

# Chaos gate: a fixed job set under seeded FaultPlans — worker kill with
# auto-respawn, coordinator crash mid-wave with fleet-journal recovery,
# and a service restart mid-queue recovered via ForgeService.recover —
# each asserting reports byte-equivalent to the undisturbed serial
# baseline, with workers_respawned / journal-recovery counters proving
# the faults actually fired.
run_gate chaos \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python scripts/chaos_gate.py || exit

# Verification fast-path gate, three scenarios (writes BENCH_pipeline.json,
# uploaded as a CI artifact): the memoized verify + cost-screened dispatch
# must keep its >=1.5x cold-run speedup with bit-identical results vs the
# uncached cascade; the cross-job shared cache + batch planner must cut
# the marginal cost of a structurally identical twin by >=1.4x vs per-job
# sessions (also bit-identical, plus a check-mode pass over the batch);
# and the learned search policy (mined priors + cost-ranked proposals)
# must keep proposals-per-win strictly below the counts-policy baseline on
# the warm-prior scenario, >=20% below it on the transfer scenario, and
# under the absolute cap — without regressing any per-job speedup.
run_gate pipeline-throughput \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.pipeline_throughput --min-speedup 1.5 \
    --min-batch-improvement 1.4 --max-proposals-per-win 5.0 || exit

# Cache warm-up (ROADMAP): CI restores results/warm_store.json from the
# actions cache; when the exact cache key missed, the workflow sets
# CI_BUILD_WARM_STORE=1 and the store is (re)built here — even over a
# prefix-restored stale file, which seeds the rebuild through family
# transfer and must not suppress it (the refreshed file is re-cached under
# the new key at job end). Local runs skip this unless opted in — the l2
# gate below uses the store whenever it exists.
if [ "${CI_BUILD_WARM_STORE:-0}" = "1" ]; then
  run_gate warm-store \
    env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/warm_store.py --out "$WARM_STORE" || exit
elif [ ! -f "$WARM_STORE" ]; then
  skip_gate warm-store "no store, CI_BUILD_WARM_STORE!=1"
fi

# Perf regression gate: re-run the l2 suite — warm-started from the store
# when present, so replay/transfer keeps it cheap — and fail on any
# per-kernel us_per_call regression >5% against a previous BENCH_l2.json
# (the run overwrites the artifact with fresh numbers on success). With no
# baseline but a warm store available (first hosted-CI run: BENCH_l2.json
# is gitignored), the suite still runs to *bootstrap* the artifact that the
# workflow then caches as the next run's baseline.
L2_ARGS=()
if [ -f BENCH_l2.json ]; then
  L2_ARGS+=(--baseline BENCH_l2.json)
fi
if [ -f "$WARM_STORE" ]; then
  L2_ARGS+=(--cache "$WARM_STORE")
fi
if [ ${#L2_ARGS[@]} -gt 0 ]; then
  run_gate l2-regression \
    env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only l2 "${L2_ARGS[@]}" || exit
else
  skip_gate l2-regression "no BENCH_l2.json baseline and no warm store"
fi
