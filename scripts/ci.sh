#!/usr/bin/env bash
# CI gate: byte-compile everything (catches syntax errors before pytest even
# collects — the seed shipped one), then run the tier-1 suite.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src tests benchmarks examples

# Registry consistency gate: the stage DAG must validate and every stage
# must have a proposer factory and >=1 issue binding, or the planner /
# proposer / issue-routing surfaces derived from it are broken by
# construction. (-W: silence runpy's already-imported RuntimeWarning.)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -W ignore::RuntimeWarning -m repro.core.stages --check

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Perf regression gate: when a previous l2 artifact exists, re-run the suite
# and fail on any per-kernel us_per_call regression >5% against it (the run
# overwrites BENCH_l2.json with the fresh numbers on success).
if [ -f BENCH_l2.json ]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only l2 --baseline BENCH_l2.json
fi
