#!/usr/bin/env bash
# CI gate: byte-compile everything (catches syntax errors before pytest even
# collects — the seed shipped one), then run the tier-1 suite.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src tests benchmarks examples
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
