"""CI gate: the Forge service must serve a kernel end to end over HTTP.

Starts the service in-process on a loopback ephemeral port, then drives it
exactly the way a tenant would — through :class:`ForgeClient`:

1. submit two kernels, the second an exact duplicate of the first;
2. assert both complete and the duplicate was *coalesced* (one engine
   execution, two byte-identical reports);
3. assert the SSE stream replays a nonzero stage-event feed that matches
   the report's stage records;
4. drain: intake closes (503 on the next submit) while finished state
   stays queryable.

Exit 0 with a "FORGE-SERVICE GATE OK" trailer on success; any assertion
failure exits nonzero (ci.sh stops at this gate).
"""

from __future__ import annotations

import json
import sys

from repro.aibench import build_program, load_specs
from repro.core.config import ForgeConfig
from repro.core.engine import KernelJob
from repro.serve.client import ForgeClient, ServiceError
from repro.serve.http import ForgeServiceServer
from repro.serve.service import ForgeService, ServiceConfig


def _job(spec):
    return KernelJob(spec.name,
                     build_program(spec.builder, spec.dims("ci"), "naive",
                                   meta=spec.meta),
                     build_program(spec.builder, spec.dims("bench"), "naive",
                                   meta=spec.meta),
                     tags=tuple(spec.tags), target_dtype=spec.target_dtype,
                     rtol=spec.rtol, atol=spec.atol, meta=dict(spec.meta))


def main() -> int:
    specs = sorted(load_specs(), key=lambda s: s.name)
    spec = specs[0]
    service = ForgeService(ForgeConfig(max_iterations=1),
                           service_config=ServiceConfig(wave_size=2))
    server = ForgeServiceServer(("127.0.0.1", 0), service)
    server.serve_background()
    print(f"[gate] service up at {server.url}")
    try:
        client = ForgeClient(server.url, api_key="ci-gate")
        client.wait_ready(timeout=30)

        r1 = client.submit(_job(spec))
        r2 = client.submit(_job(spec))          # exact duplicate
        print(f"[gate] submitted {r1['job_id']} + duplicate {r2['job_id']} "
              f"(deduped={r2['deduped']})")
        assert r2["deduped"], "duplicate submit was not coalesced"

        s1 = client.wait(r1["job_id"], timeout=600)
        s2 = client.wait(r2["job_id"], timeout=600)
        assert s1["state"] == "done", f"primary ended {s1['state']}"
        assert s2["state"] == "done", f"duplicate ended {s2['state']}"

        canon = lambda d: json.dumps(d, sort_keys=True)  # noqa: E731
        assert canon(s1["report"]) == canon(s2["report"]), \
            "coalesced duplicate got a different report"
        stats = client.stats()
        assert stats["engine"]["jobs"] == 1, \
            f"dedup failed: engine ran {stats['engine']['jobs']} jobs"

        events = list(client.events(r1["job_id"]))
        stages = [d for e, d in events if e == "stage"]
        expected = s1["report"]["jobs"][0]["stages"]
        assert stages, "SSE stream carried zero stage events"
        assert stages == expected, \
            f"SSE streamed {len(stages)} stage records, " \
            f"report holds {len(expected)}"
        print(f"[gate] {len(stages)} stage events streamed over SSE; "
              f"speedup {s1['report']['jobs'][0]['speedup']:.2f}x")

        client.drain()
        try:
            client.submit(_job(specs[1]))
        except ServiceError as exc:
            assert exc.status == 503, f"drained submit got {exc.status}"
        else:
            raise AssertionError("drained service accepted a submission")
        assert client.status(r1["job_id"])["state"] == "done", \
            "drain lost finished job state"
        print("[gate] drain closed intake; finished state still served")
    finally:
        server.shutdown_all(drain=True)
    print("FORGE-SERVICE GATE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
