#!/usr/bin/env python
"""CI gate: serial / thread / process backends must be result-equivalent.

Runs a small fixed job set (one per structural family, plus a family twin so
the in-batch transfer path is exercised) through a fresh Forge per backend
and fails if any per-kernel TransformLog, fingerprint, optimized time, or
canonical schedule diverges from the serial reference. This is the
executable form of the engine's core contract: *where* a job ran can never
change *what* it produced.

    PYTHONPATH=src python scripts/backend_equivalence.py [--workers N]
                                                         [--backends a,b,c]
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

# the fixed gate set: two GEMM-family structures, one matmul-family, and a
# conv, so equivalence is checked across pallas-templated and XLA-only paths
GATE_SPECS = ("gemm_bias_gelu", "gemm_swish_tanh_scale", "matmul_t_gelu",
              "conv2d_gelu_scale")


def build_jobs():
    from repro.aibench import build_program, load_specs
    from repro.core import KernelJob

    specs = {s.name: s for s in load_specs()}
    jobs = []
    for name in GATE_SPECS:
        s = specs[name]
        jobs.append(KernelJob(
            s.name,
            build_program(s.builder, s.dims("ci"), "naive", meta=s.meta),
            build_program(s.builder, s.dims("bench"), "naive", meta=s.meta),
            tags=tuple(s.tags), target_dtype=s.target_dtype,
            rtol=s.rtol, atol=s.atol, meta=dict(s.meta)))
    # family twin of the first job at halved dims: forces the two-phase
    # leader/follower transfer path on every backend
    s = specs[GATE_SPECS[0]]
    jobs.append(KernelJob(
        f"{s.name}_twin",
        build_program(s.builder,
                      {k: max(32, v // 2) for k, v in s.dims("ci").items()},
                      "naive", meta=s.meta),
        build_program(s.builder,
                      {k: max(64, v // 2) for k, v in s.dims("bench").items()},
                      "naive", meta=s.meta),
        tags=tuple(s.tags), target_dtype=s.target_dtype,
        rtol=s.rtol, atol=s.atol, meta=dict(s.meta)))
    return jobs


def run_backend(backend: str, workers: int):
    from repro.forge import Forge, ForgeConfig
    from repro.ir.fingerprint import program_canonical

    t0 = time.monotonic()
    with Forge(ForgeConfig(execution_backend=backend,
                           workers=workers)) as forge:
        report = forge.optimize_batch(build_jobs())
    rows = {}
    for r in report.results:
        rows[r.job.name] = {
            "fingerprint": r.fingerprint,
            "transform_log": r.result.transform_log.to_list(),
            "speedup": round(r.result.speedup, 9),
            "optimized_time": r.result.optimized_time,
            "canonical_schedule": program_canonical(
                r.result.bench_program)["schedule"],
            "cache_hit": r.cache_hit,
            "transfer": r.transfer,
        }
    return rows, time.monotonic() - t0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--backends", default="serial,thread,process",
                    help="comma-separated subset to compare (first entry "
                         "is the reference)")
    args = ap.parse_args()
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]
    if len(backends) < 2:
        ap.error("need at least two backends to compare")

    print(f"== backend equivalence gate ({len(GATE_SPECS) + 1} jobs, "
          f"workers={args.workers}) ==")
    results = {}
    for backend in backends:
        rows, dt = run_backend(backend, args.workers)
        results[backend] = rows
        transfers = sum(1 for v in rows.values() if v["transfer"])
        print(f"  {backend:8s} {dt:6.1f}s  {len(rows)} kernels, "
              f"{transfers} transfer(s)")

    ref_name, ref = backends[0], results[backends[0]]
    failures = []
    for backend in backends[1:]:
        for name, row in results[backend].items():
            for field in ("fingerprint", "transform_log", "speedup",
                          "optimized_time", "canonical_schedule",
                          "cache_hit", "transfer"):
                if row[field] != ref[name][field]:
                    failures.append((backend, name, field))
                    print(f"  DIVERGED {backend}/{name}.{field}:\n"
                          f"    {ref_name}: {ref[name][field]!r}\n"
                          f"    {backend}: {row[field]!r}")
    if failures:
        print(f"\nFAIL: {len(failures)} divergence(s) vs {ref_name}")
        return 1
    print(f"\nbackend equivalence OK ({', '.join(backends)}: identical "
          f"logs, fingerprints, speedups)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
