#!/usr/bin/env python
"""CI gate: serial / thread / process / remote backends must be
result-equivalent.

Runs a small fixed job set (one per structural family, plus a family twin so
the in-batch transfer path is exercised) through two rounds per backend —
cold (empty history: cost-ranked ordering only) and warm-prior (fresh store,
history mined from the cold round: the mined-prior ordering is live) — and
fails if any per-kernel TransformLog, fingerprint, optimized time, or
canonical schedule diverges from the serial reference in either round. This
is the executable form of the engine's core contract: *where* a job ran can
never change *what* it produced — including under the learned search policy,
whose priors are batch-frozen precisely so completion order can't leak into
candidate ordering.

    PYTHONPATH=src python scripts/backend_equivalence.py [--workers N]
                                                         [--backends a,b,c]

``--backends serial,remote`` spins up a loopback distributed fleet
(``--workers`` forge-worker processes against an ephemeral coordinator
port) and proves the remote backend produces the same bytes as serial —
the ``remote-equivalence`` gate in ``scripts/ci.sh``.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

# the fixed gate set (one job per structural family plus a family twin that
# forces the two-phase leader/follower transfer path) is shared with the
# pipeline-throughput benchmark, so backend equivalence and fast-path
# equivalence are proven over the same jobs
from benchmarks.pipeline_throughput import GATE_SPECS, build_jobs  # noqa: E402


def run_backend(backend: str, workers: int):
    from repro.core import ForgeConfig, ForgePipeline, OptimizationEngine
    from repro.core.history import History
    from repro.ir.fingerprint import program_canonical

    t0 = time.monotonic()
    cfg = ForgeConfig(execution_backend=backend, workers=workers)
    hist = History()

    def one_round(tag: str, rows: dict):
        # fresh engine/store per round; the history is shared, so the warm
        # round's mined priors are fed by the cold round's records (on the
        # process backend those records round-tripped the results queue)
        eng = OptimizationEngine(ForgePipeline(config=cfg, history=hist),
                                 config=cfg)
        try:
            for r in eng.run_batch(build_jobs()):
                rows[f"{r.job.name}#{tag}"] = {
                    "fingerprint": r.fingerprint,
                    "transform_log": r.result.transform_log.to_list(),
                    "speedup": round(r.result.speedup, 9),
                    "optimized_time": r.result.optimized_time,
                    "canonical_schedule": program_canonical(
                        r.result.bench_program)["schedule"],
                    "cache_hit": r.cache_hit,
                    "transfer": r.transfer,
                }
        finally:
            eng.close()

    rows: dict = {}
    one_round("cold", rows)
    one_round("warm", rows)
    return rows, time.monotonic() - t0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--backends", default="serial,thread,process",
                    help="comma-separated subset to compare (first entry "
                         "is the reference)")
    args = ap.parse_args()
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]
    if len(backends) < 2:
        ap.error("need at least two backends to compare")

    print(f"== backend equivalence gate ({len(GATE_SPECS) + 1} jobs x "
          f"cold+warm-prior rounds, workers={args.workers}) ==")
    results = {}
    for backend in backends:
        rows, dt = run_backend(backend, args.workers)
        results[backend] = rows
        transfers = sum(1 for v in rows.values() if v["transfer"])
        print(f"  {backend:8s} {dt:6.1f}s  {len(rows)} kernel rounds, "
              f"{transfers} transfer(s)")

    ref_name, ref = backends[0], results[backends[0]]
    failures = []
    for backend in backends[1:]:
        for name, row in results[backend].items():
            for field in ("fingerprint", "transform_log", "speedup",
                          "optimized_time", "canonical_schedule",
                          "cache_hit", "transfer"):
                if row[field] != ref[name][field]:
                    failures.append((backend, name, field))
                    print(f"  DIVERGED {backend}/{name}.{field}:\n"
                          f"    {ref_name}: {ref[name][field]!r}\n"
                          f"    {backend}: {row[field]!r}")
    if failures:
        print(f"\nFAIL: {len(failures)} divergence(s) vs {ref_name}")
        return 1
    print(f"\nbackend equivalence OK ({', '.join(backends)}: identical "
          f"logs, fingerprints, speedups)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
