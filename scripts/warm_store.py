#!/usr/bin/env python
"""Cache warm-up tooling (ROADMAP item): build a pre-seeded ResultStore for
the KernelBench-L2 suite so cold CI runs start from replay/transfer seeds.

    PYTHONPATH=src python scripts/warm_store.py [--out results/warm_store.json]
                                                [--workers N] [--backend B]
                                                [--families gemm,matmul]

Runs the full L2 suite once with a persistent store at ``--out`` and prints
the store/engine summary. CI restores the artifact (actions/cache keyed on
the KB content hash + policy signature, with prefix fallbacks) and passes it
to ``benchmarks.run --cache`` — an exact key match replays every kernel; a
near miss (KB or policy drifted) still transfers through the family index,
because family lookups are deliberately not KB-versioned.

The store is self-invalidating: exact keys fold in the KB content hash and
the config policy signature, so a stale warm store can never produce a wrong
result — only fewer hits.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/warm_store.json",
                    help="where to write the pre-seeded ResultStore")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--backend", default="thread",
                    choices=["serial", "thread", "process"])
    ap.add_argument("--families", default=None,
                    help="comma-separated family subset (default: all)")
    args = ap.parse_args()

    from repro.aibench import SuiteRunner
    from repro.forge import ForgeConfig

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    families = ([f.strip() for f in args.families.split(",") if f.strip()]
                if args.families else None)
    config = ForgeConfig(workers=args.workers,
                         execution_backend=args.backend,
                         cache_path=str(out))
    runner = SuiteRunner(config, families=families)
    with runner:
        summary = runner.run()

    store = runner.forge.cache
    stats = summary.engine_stats
    print(f"\nwarm store: {out} ({len(store)} entries, "
          f"{len(store.family_sizes())} families)")
    print(f"policy signature: {config.policy_signature()}")
    print(f"kb content hash:  {runner.forge.pipeline.kb.content_hash()}")
    if stats:
        print(f"engine: {stats.jobs} jobs, {stats.cache_hits} hits, "
              f"{stats.family_transfers} transfers while seeding")
    if not summary.all_correct:
        print("FAIL: suite produced incorrect kernels; not a usable store")
        return 1
    print("warm store OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
