"""CI chaos gate: seeded fault injection must not change results.

Runs a fixed 3-kernel job set under three deterministic
:class:`repro.core.faults.FaultPlan` scenarios and asserts every
disturbed run produces reports **byte-equivalent** to an undisturbed
serial baseline — with the recovery counters proving the faults actually
fired (a green run can never mean "the crash never happened"):

A. **Worker kill + auto-respawn** — spawned fleet worker 0 dies on its
   first job (``kill_worker_after_jobs=0``); the coordinator re-dispatches
   the orphaned task and respawns a replacement.
B. **Coordinator crash mid-wave + journal recovery** — the coordinator
   crashes right after journaling a completion; a successor Forge opens
   the same fleet journal, recovers the in-flight tasks, resumes them,
   and re-runs the batch to the baseline result.
C. **Service restart mid-queue** — the service dispatcher crashes before
   wave 1's terminal journal commit with three jobs accepted;
   ``ForgeService.recover`` replays the submit journal and every job
   completes exactly once on the restarted service.

Every run is cold (no cache_path) so cache-hit flags match the baseline.
Exit 0 with a "CHAOS GATE OK" trailer on success.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

from repro.aibench import build_program, load_specs
from repro.core import Forge, ForgeConfig, OptimizationReport
from repro.core.engine import KernelJob
from repro.core.faults import FaultPlan, InjectedCrash
from repro.serve.service import ForgeService, ServiceConfig

MAX_ITERATIONS = 1      # chaos semantics are independent of search depth


def _job(spec):
    return KernelJob(spec.name,
                     build_program(spec.builder, spec.dims("ci"), "naive",
                                   meta=spec.meta),
                     build_program(spec.builder, spec.dims("bench"), "naive",
                                   meta=spec.meta),
                     tags=tuple(spec.tags), target_dtype=spec.target_dtype,
                     rtol=spec.rtol, atol=spec.atol, meta=dict(spec.meta))


def _comparable(report_dict):
    """Byte-comparable report form: drop the two keys that legitimately
    differ across backends (config carries execution_backend; verify
    counters depend on cache locality)."""
    d = dict(report_dict)
    d.pop("config", None)
    d.pop("verify_stats", None)
    return json.dumps(d, sort_keys=True)


def scenario_a(specs, baseline_batch):
    """Worker kill -> re-dispatch + auto-respawn, report unchanged."""
    plan = FaultPlan(kill_worker_after_jobs=0, worker_index=0)
    cfg = ForgeConfig(execution_backend="remote", workers=2,
                      max_iterations=MAX_ITERATIONS,
                      fleet_heartbeat_s=0.5, fleet_heartbeat_timeout_s=3.0,
                      fault_spec=plan.to_json(), fleet_max_respawns=2)
    forge = Forge(cfg)
    try:
        report = forge.optimize_batch([_job(s) for s in specs])
        fleet = forge.engine._get_executor().fleet
        deadline = time.monotonic() + 30
        while fleet.workers_respawned < 1 and time.monotonic() < deadline:
            time.sleep(0.05)
        tel = fleet.telemetry()
    finally:
        forge.close()
    print(f"[chaos:A] telemetry {tel}")
    assert tel["workers_lost"] >= 1, "worker kill never happened"
    assert tel["tasks_redispatched"] >= 1, "no task was re-dispatched"
    assert tel["workers_respawned"] >= 1, "no replacement was spawned"
    assert _comparable(report.as_dict()) == baseline_batch, \
        "scenario A report diverged from the serial baseline"


def scenario_b(specs, baseline_batch, tmpdir):
    """Coordinator crash after a journaled completion -> successor
    recovers the in-flight tasks from the fleet journal."""
    journal = os.path.join(tmpdir, "fleet.wal")
    # completions are counted across runs: len(jobs) keys completions,
    # then the crash lands on the first *job* completion of the next wave
    plan = FaultPlan(crash_coordinator_after_completions=len(specs) + 1)
    cfg = ForgeConfig(execution_backend="remote", workers=2,
                      max_iterations=MAX_ITERATIONS,
                      fault_spec=plan.to_json(), fleet_journal_path=journal)
    forge1 = Forge(cfg)
    crashed = False
    try:
        forge1.optimize_batch([_job(s) for s in specs])
    except InjectedCrash as exc:
        crashed = True
        print(f"[chaos:B] injected: {exc}")
    finally:
        forge1.close()
    assert crashed, "coordinator crash never fired"

    cfg2 = ForgeConfig(execution_backend="remote", workers=2,
                       max_iterations=MAX_ITERATIONS,
                       fleet_journal_path=journal)
    forge2 = Forge(cfg2)
    try:
        fleet = forge2.engine._get_executor().fleet
        recovered = fleet.tasks_recovered
        assert recovered > 0, "journal recovery found nothing in flight"
        fleet.wait_for_workers(1, timeout=120)
        resumed = fleet.resume_pending()
        assert len(resumed) == recovered, \
            f"resumed {len(resumed)}/{recovered} recovered tasks"
        report = forge2.optimize_batch([_job(s) for s in specs])
        tel = fleet.telemetry()
    finally:
        forge2.close()
    print(f"[chaos:B] recovered {recovered} task(s); telemetry {tel}")
    assert _comparable(report.as_dict()) == baseline_batch, \
        "scenario B report diverged from the serial baseline"


def scenario_c(specs, baseline_per_job, tmpdir):
    """Service dispatcher crash mid-queue -> ForgeService.recover replays
    the submit journal; every job completes exactly once."""
    journal = os.path.join(tmpdir, "service.wal")
    cfg = ForgeConfig(max_iterations=MAX_ITERATIONS)
    plan = FaultPlan(crash_dispatcher_wave=1,
                     crash_dispatcher_point="before-journal")
    svc = ForgeService(cfg, service_config=ServiceConfig(wave_size=1),
                       journal_path=journal, fault_plan=plan)
    receipts = [svc.submit_job(_job(s), client="chaos") for s in specs]
    deadline = time.monotonic() + 300
    while not svc.dispatcher_crashed:
        assert time.monotonic() < deadline, "dispatcher never crashed"
        time.sleep(0.05)
    svc.shutdown(drain=False)
    assert plan.fired.get("crash_dispatcher:before-journal") == 1

    svc2 = ForgeService.recover(journal, config=cfg,
                                service_config=ServiceConfig(wave_size=1))
    try:
        js = svc2.journal_stats()
        print(f"[chaos:C] recovery {js}")
        assert js["jobs_recovered"] == len(specs)
        assert js["jobs_requeued"] == len(specs), \
            "recovery must requeue every non-terminal job"
        for receipt, want in zip(receipts, baseline_per_job):
            status = svc2.wait(receipt["job_id"], timeout=600)
            assert status["state"] == "done", status
            assert _comparable(status["report"]) == want, \
                f"recovered job {status['name']} diverged from baseline"
        # exactly once: the recovered engine ran each job a single time
        assert svc2.forge.stats.jobs == len(specs), \
            f"expected {len(specs)} engine runs, saw {svc2.forge.stats.jobs}"
    finally:
        svc2.shutdown(drain=True)


def main() -> int:
    specs = sorted(load_specs(), key=lambda s: s.name)[:3]
    names = [s.name for s in specs]
    print(f"[chaos] job set: {names}")

    # undisturbed serial baselines (cold): one batch report for the fleet
    # scenarios, per-job reports (same arrival order) for the service one
    with Forge(ForgeConfig(execution_backend="serial",
                           max_iterations=MAX_ITERATIONS)) as forge:
        baseline_batch = _comparable(
            forge.optimize_batch([_job(s) for s in specs]).as_dict())
    with Forge(ForgeConfig(max_iterations=MAX_ITERATIONS)) as forge:
        baseline_per_job = [
            _comparable(forge.optimize(_job(s)).as_dict()) for s in specs]

    with tempfile.TemporaryDirectory(prefix="chaos-gate-") as tmpdir:
        scenario_a(specs, baseline_batch)
        print("[chaos] scenario A (worker kill + respawn) OK")
        scenario_b(specs, baseline_batch, tmpdir)
        print("[chaos] scenario B (coordinator crash + journal) OK")
        scenario_c(specs, baseline_per_job, tmpdir)
        print("[chaos] scenario C (service restart mid-queue) OK")

    print("CHAOS GATE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
